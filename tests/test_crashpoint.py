"""Deterministic crash-point injection (PR 9): wrapper stacking, site
addressing (role + source span + nth), one-shot arming, the daemon's
firing accounting, and an end-to-end armed cloud run that recovers
bit-identically.
"""

import sys
import threading
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from repro.core import FaultPlan  # noqa: E402
from repro.core.faults import MonitorDaemon  # noqa: E402
from repro.core.space import (CrashPointFired, CrashSpec,  # noqa: E402
                              TupleSpace, find_checked, find_crashpoint,
                              make_backend, role)

_THIS = "tests/test_crashpoint.py"


def _put_task(ts, tid):
    ts.put(("task", tid), "wire")


#: The armed source line — the put inside ``_put_task``.
_PUT_LINE = _put_task.__code__.co_firstlineno + 1


def _spec(**kw):
    base = dict(site_id="s", role="manager", path=_THIS, line=_PUT_LINE,
                nth=1, when="after")
    base.update(kw)
    return CrashSpec(**base)


def _armed(spec):
    ts = TupleSpace(backend="crashpoint+sharded")
    cp = find_crashpoint(ts.backend)
    cp.arm(spec)
    return ts, cp


def test_wrapper_stacks_and_is_discoverable():
    b = make_backend("crashpoint+checked+sharded:2")
    assert find_crashpoint(b) is not None
    assert find_checked(b) is not None
    ts = TupleSpace(backend="crashpoint+sharded")
    assert find_crashpoint(ts.backend) is not None
    assert find_crashpoint(make_backend("sharded")) is None


def test_disarmed_is_pure_delegation():
    ts = TupleSpace(backend="crashpoint+sharded")
    ts.put(("task", "t1"), "x")
    assert ts.try_get(("task", "t1")) == (("task", "t1"), "x")
    st = ts.stats()
    assert st["crashpoint_hits"] == 0 and st["crashpoint_firings"] == 0


def test_spec_validates_when_and_nth():
    with pytest.raises(ValueError):
        _spec(when="during")
    with pytest.raises(ValueError):
        _spec(nth=0)


def test_fires_on_nth_matching_op_for_matching_role_only():
    ts, cp = _armed(_spec(nth=2))
    with role("handler"):
        _put_task(ts, "h1")            # wrong role: not even counted
    with role("manager"):
        _put_task(ts, "m1")            # hit 1 of 2: no fire
        with pytest.raises(CrashPointFired):
            _put_task(ts, "m2")        # hit 2: fire
    # when="after": the write landed before the crash
    assert ts.try_read(("task", "m2")) is not None
    assert cp.hits == 2 and len(cp.firings) == 1
    assert cp.firings[0]["site"] == "s" and cp.firings[0]["op"] == "put"


def test_arming_is_one_shot():
    """The revived thread re-traverses the same site without dying: the
    hit counter moves past nth and never resets."""
    ts, cp = _armed(_spec())
    with role("manager"):
        with pytest.raises(CrashPointFired):
            _put_task(ts, "a")
        _put_task(ts, "b")
        _put_task(ts, "c")
    assert len(cp.firings) == 1 and cp.hits == 3


def test_when_before_leaves_nothing_written():
    ts, cp = _armed(_spec(when="before"))
    with role("manager"), pytest.raises(CrashPointFired):
        _put_task(ts, "x")
    assert ts.try_read(("task", "x")) is None


def test_other_source_lines_do_not_match():
    ts, cp = _armed(_spec())
    with role("manager"):
        ts.put(("task", "direct"), "x")    # this line is not the site
    assert cp.hits == 0 and cp.firings == []


def test_disarm_stops_matching():
    ts, cp = _armed(_spec())
    cp.disarm()
    with role("manager"):
        _put_task(ts, "a")
    assert cp.hits == 0


def test_daemon_accounts_firings_like_interval_crashes():
    """Satellite 2: CrashPointBackend firings surface in the same
    MonitorDaemon counters interval firings do — per-tenant for
    managers, fleet-wide for handlers."""
    ts, cp = _armed(_spec())
    with role("manager"), pytest.raises(CrashPointFired):
        _put_task(ts, "m")
    daemon = MonitorDaemon(plan=FaultPlan(),
                           manager_crashes=[threading.Event()],
                           crashpoint=cp)
    daemon._account_crashpoint()
    assert daemon.crashpoint_firings == 1
    assert daemon.manager_crash_firings_by[0] == 1
    assert daemon.handler_crash_firings == 0
    # drained: accounting again is a no-op
    daemon._account_crashpoint()
    assert daemon.crashpoint_firings == 1
    # a handler-role firing lands in the fleet counter instead
    cp.arm(_spec(site_id="s2", role="handler"))
    with role("handler"), pytest.raises(CrashPointFired):
        _put_task(ts, "h")
    daemon._account_crashpoint()
    assert daemon.crashpoint_firings == 2
    assert daemon.handler_crash_firings == 1
    assert daemon.manager_crash_firings_by[0] == 1


def test_end_to_end_armed_run_recovers_bit_identically():
    """Arm one mid-training Manager site through the full cloud stack:
    the run must complete, revive the Manager, and match the crash-free
    baseline bit-for-bit with zero leaks and zero races."""
    from tools.crash_sweep import sweep, sweep_sites
    target = "manager:program.record_loss:put[losshist]#0"
    sites = [s for s in sweep_sites() if s.site_id == target]
    assert sites, "site registry lost the record_loss put"
    (r,) = sweep(sites, backends=("crashpoint+checked+sharded",),
                 verbose=False)
    assert r.reached, "the armed site was never traversed"
    assert r.ok, r.failures
    assert r.revivals >= 1
