"""Admission fence (PR 8): the Manager refuses to overlap DAG-concurrent
stages whose *declared* effects conflict — they run serialized with one
loud warning per stage pair — while declared-clean stages keep the full
frontier overlap.
"""

import logging
import threading

import pytest

from repro.core.handler import Handler, SpeedBox
from repro.core.manager import Manager, ManagerConfig
from repro.core.program import WorkloadProgram, deletes, reads, writes
from repro.core.space import ANY, TupleSpace
from repro.core.tasks import TaskDesc
from repro.programs.mlp import ACTIVATION

MGR_LOGGER = "repro.core.manager"


class FenceDiamond(WorkloadProgram):
    """a -> (b1 | b2) -> c. ``b1``/``b2`` are real task stages (distinct
    layers), so both can be in flight together. ``conflicting=True``
    declares (and performs) a read-modify-write of the shared ``("acc",)``
    cell from both; ``False`` declares disjoint ``layer`` pins and writes
    disjoint keys. ``events`` journals stage launches and combines in
    Manager order."""

    name = "fence_diamond"

    def __init__(self, conflicting: bool, rounds: int = 2,
                 width: int = 8) -> None:
        self.conflicting = conflicting
        self.rounds = rounds
        self.width = width
        self.events: list[tuple] = []

    def setup(self, ts) -> None:
        import numpy as np
        for rnd in range(self.rounds):
            for layer in (1, 2):
                if ts.try_read(("pre", layer, rnd)) is None:
                    ts.put(("pre", layer, rnd),
                           np.linspace(-1, 1, self.width)
                           .astype(np.float32))

    def n_rounds(self) -> int:
        return self.rounds

    def stage_names(self, rnd):
        return ["a", "b1", "b2", "c"]

    def stage_deps(self, rnd):
        return {"b1": ["a"], "b2": ["a"], "c": ["b1", "b2"]}

    def stage_tasks(self, ts, rnd, stage):
        self.events.append(("launch", rnd, stage))
        if stage in ("a", "c"):
            return []
        layer = 1 if stage == "b1" else 2
        return [TaskDesc(ACTIVATION, layer, rnd, rnd, 0, 0, 0, self.width)]

    def combine(self, ts, rnd, stage, mgr) -> None:
        self.events.append(("combine", rnd, stage))
        if stage not in ("b1", "b2"):
            return
        layer = 1 if stage == "b1" else 2
        if self.conflicting:
            # Order-sensitive shared-cell RMW: only serialization keeps
            # the final value deterministic.
            hit = ts.try_read(("acc",))
            acc = hit[1] if hit else 1.0
            ts.delete(("acc",))
            ts.put(("acc",), acc * 3.0 + layer)
        else:
            ts.put(("out", layer, rnd), float(layer))

    def finish_round(self, ts, rnd) -> None:
        ts.delete(("actpart", ANY, rnd, ANY, ANY))
        ts.delete(("done", ANY, ANY, rnd, ANY, ANY, ANY, ANY, ANY))

    def stage_effects(self, rnd):
        if self.conflicting:
            b = (reads("acc"), writes("acc"), deletes("acc"))
            b1 = b2 = b
        else:
            b1 = (writes("out", layer=1),)
            b2 = (writes("out", layer=2),)
        return {"a": (), "c": (), "b1": b1, "b2": b2}


def _run(prog: FenceDiamond, width: int, fence: bool = True) -> TupleSpace:
    ts = TupleSpace()
    stop = threading.Event()
    mgr = Manager(ts=ts, program=prog,
                  cfg=ManagerConfig(task_cap=64.0, initial_timeout=30.0,
                                    max_inflight_stages=width,
                                    effect_fence=fence),
                  stop_event=stop)
    handler = Handler(ts=ts, name="h0", speed=SpeedBox(1.0), capacity=64.0,
                      time_scale=1e-9, stop_event=stop)
    threads = [threading.Thread(target=mgr.run, daemon=True),
               threading.Thread(target=handler.run, daemon=True)]
    for t in threads:
        t.start()
    ts.read(("mstate", "finished"), timeout=30.0)
    stop.set()
    for t in threads:
        t.join(timeout=5.0)
    return ts


def _idx(events, kind, rnd, stage):
    return events.index((kind, rnd, stage))


def test_conflicting_stages_serialized_with_one_warning(caplog):
    prog = FenceDiamond(conflicting=True)
    with caplog.at_level(logging.WARNING, logger=MGR_LOGGER):
        ts = _run(prog, width=4)
    # serialized: b2 admitted only after b1's combine, every round
    for rnd in range(2):
        assert _idx(prog.events, "launch", rnd, "b2") \
            > _idx(prog.events, "combine", rnd, "b1")
    warnings = [r for r in caplog.records
                if "admission fence" in r.getMessage()]
    assert len(warnings) == 1              # once per stage pair, not per round
    assert "'b1'" in warnings[0].getMessage()
    assert "'b2'" in warnings[0].getMessage()
    # bit-identical to the sequential scheduler
    seq_ts = _run(FenceDiamond(conflicting=True), width=1)
    assert ts.try_read(("acc",))[1] == seq_ts.try_read(("acc",))[1]


def test_declared_clean_stages_overlap_without_warning(caplog):
    prog = FenceDiamond(conflicting=False)
    with caplog.at_level(logging.WARNING, logger=MGR_LOGGER):
        ts = _run(prog, width=4)
    # overlapped: b2 admitted while b1 is still in flight, every round
    for rnd in range(2):
        assert _idx(prog.events, "launch", rnd, "b2") \
            < _idx(prog.events, "combine", rnd, "b1")
    assert not any("admission fence" in r.getMessage()
                   for r in caplog.records)
    assert ts.try_read(("out", 1, 1)) and ts.try_read(("out", 2, 1))


def test_fence_off_observes_only(caplog):
    prog = FenceDiamond(conflicting=True)
    with caplog.at_level(logging.WARNING, logger=MGR_LOGGER):
        _run(prog, width=4, fence=False)
    assert _idx(prog.events, "launch", 0, "b2") \
        < _idx(prog.events, "combine", 0, "b1")
    assert not any("admission fence" in r.getMessage()
                   for r in caplog.records)
