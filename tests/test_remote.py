"""RemoteBackend ⇄ TSServer (PR 10): the full SpaceBackend protocol over
the wire — blocking ops with server-side waiters, deadline conversion,
pipelined concurrent waiters across connections, the batched-framing
round-trip budget, the invalidation-coherent read-through cache,
server restart/reconnect surfaces, role/context transmission for
server-side sanitizers, and the facade's numpy key canonicalization."""

import threading
import time

import numpy as np
import pytest

from repro.core.space import (ANY, RemoteBackend, RemoteSpaceError, TSServer,
                              TSTimeout, TupleSpace, canonicalize_key,
                              make_backend, role)
from repro.core.space.remote import server_timeout
from repro.core.space.server import WAITER_SLICE


@pytest.fixture
def server():
    srv = TSServer("sharded:4").start()
    yield srv
    srv.close()


@pytest.fixture
def rb(server):
    backend = RemoteBackend(addr=server.addr, cache_subjects=())
    yield backend
    backend.close()


# ------------------------------------------------------------- basic ops
def test_full_protocol_surface(rb):
    rb.put(("w", 0), np.arange(4.0))
    rb.put_many([(("task", i), f"t{i}") for i in range(5)])
    k, v = rb.read(("w", 0))
    assert k == ("w", 0) and v[2] == 2.0
    assert rb.try_read(("nope", 0)) is None
    assert rb.count(("task", ANY)) == 5
    assert sorted(rb.keys(("task", ANY))) == [("task", i) for i in range(5)]
    k, v = rb.get(("task", 0))
    assert v == "t0"
    assert rb.try_get(("task", 1))[1] == "t1"
    assert rb.delete(("task", 2)) == 1
    batch = rb.take_batch(("task", ANY), 10, timeout=1.0)
    assert sorted(v for _, v in batch) == ["t3", "t4"]
    assert rb.wait_count(("w", ANY), 1, timeout=1.0) >= 1
    snap = rb.snapshot()
    assert ("w", 0) in snap
    assert rb.stats()["puts"] >= 6


def test_fifo_take_order_preserved(rb):
    for i in range(8):
        rb.put(("task", i), i)
    got = [v for _, v in rb.take_batch(("task", ANY), 8, timeout=1.0)]
    assert got == list(range(8))      # global_seq FIFO survives the wire


def test_blocking_read_woken_by_later_put(rb, server):
    out = []
    th = threading.Thread(
        target=lambda: out.append(rb.read(("late", 0), timeout=5.0)))
    th.start()
    time.sleep(0.1)
    other = RemoteBackend(addr=server.addr, cache_subjects=())
    other.put(("late", 0), "v")
    th.join(3.0)
    other.close()
    assert out and out[0][1] == "v"


def test_concurrent_blocking_waiters_across_connections(server):
    """N waiters parked across two connections each get exactly one of N
    tuples — server-side waiter parking must not wedge the connection's
    pipeline (each blocking op runs on its own dispatch thread)."""
    clients = [RemoteBackend(addr=server.addr, cache_subjects=())
               for _ in range(2)]
    results = []
    lock = threading.Lock()

    def waiter(c):
        got = c.get(("job", ANY), timeout=5.0)
        with lock:
            results.append(got)

    threads = [threading.Thread(target=waiter, args=(clients[i % 2],))
               for i in range(6)]
    for t in threads:
        t.start()
    time.sleep(0.2)
    feeder = RemoteBackend(addr=server.addr, cache_subjects=())
    feeder.put_many([(("job", i), i) for i in range(6)])
    for t in threads:
        t.join(5.0)
    for c in clients + [feeder]:
        c.close()
    assert sorted(v for _, v in results) == list(range(6))


# ------------------------------------------------- deadlines (satellite 2)
def test_server_timeout_conversion_unit():
    assert server_timeout(None) is None
    now = time.monotonic()
    remaining = server_timeout(now + 2.0)
    assert 1.9 < remaining <= 2.0
    # A deadline already in the past must clamp to zero, not go negative
    # (a negative server timeout would mean "wait forever" in some APIs —
    # exactly the over-wait the conversion exists to prevent).
    assert server_timeout(now - 5.0) == 0.0


def test_timeout_is_relative_to_call_entry(rb):
    t0 = time.monotonic()
    with pytest.raises(TSTimeout):
        rb.get(("never", 0), timeout=0.3)
    elapsed = time.monotonic() - t0
    assert 0.25 < elapsed < 2.0     # honored server-side, no over-wait


def test_wait_count_timeout(rb):
    rb.put(("d", 0), 1)
    with pytest.raises(TSTimeout):
        rb.wait_count(("d", ANY), 3, timeout=0.2)
    assert rb.wait_count(("d", ANY), 1, timeout=0.2) == 1


# --------------------------------------------- batched framing (tentpole)
def test_pouch_drain_two_round_trips(rb):
    """The acceptance gate: one put_many + one take_batch = exactly two
    request frames, regardless of batch size."""
    rb.put_many([(("task", i), np.full(128, i)) for i in range(64)])
    before = rb.round_trips
    rb.put_many([(("r", i), np.full(64, i)) for i in range(64)])
    out = rb.take_batch(("task", ANY), 64, timeout=1.0)
    assert len(out) == 64
    assert rb.round_trips - before == 2


def test_error_propagation(rb):
    with pytest.raises(TypeError):
        rb.put("not-a-tuple", 1)    # client-side validate_key, no wire trip
    # A server-side error comes back typed by name over the wire and the
    # connection survives it.
    with pytest.raises(ValueError):
        rb._request("frobnicate", ())
    rb.ping()


# ------------------------------------------------------ read-through cache
def test_cache_hit_skips_round_trip(server):
    rb = RemoteBackend(addr=server.addr, cache_subjects={"w"})
    try:
        rb.put(("w", 1), np.arange(3.0))
        rb.read(("w", 1))
        before = rb.round_trips
        for _ in range(5):
            k, v = rb.read(("w", 1))
        assert rb.round_trips == before       # all served locally
        assert rb.cache_hits >= 5
        assert v[1] == 1.0
    finally:
        rb.close()


def test_cache_invalidated_by_version_bump(server):
    """Write-through invalidation: a mutation by ANOTHER client must
    evict this client's cached entry (the ``("w", l)``/``("wver", l)``
    commit cycle)."""
    reader = RemoteBackend(addr=server.addr, cache_subjects={"w", "wver"})
    writer = RemoteBackend(addr=server.addr, cache_subjects=())
    try:
        writer.put(("w", 0), np.zeros(4))
        writer.put(("wver", 0), 0)
        assert reader.read(("w", 0))[1][0] == 0.0
        assert reader.read(("wver", 0))[1] == 0
        # commit: delete + re-put (both journal, both must invalidate)
        writer.delete(("w", 0))
        writer.put(("w", 0), np.ones(4))
        writer.put(("wver", 0), 1)
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            if (reader.read(("wver", 0))[1] == 1
                    and reader.read(("w", 0))[1][0] == 1.0):
                break
            time.sleep(0.01)
        assert reader.read(("wver", 0))[1] == 1
        assert reader.read(("w", 0))[1][0] == 1.0
    finally:
        reader.close()
        writer.close()


def test_cache_store_skipped_when_invalidated_in_flight(server):
    """The stale-store race: a read response that observed pre-commit
    state must NOT enter the cache when the commit's invalidation was
    drained while the request was in flight — the demux thread bumps the
    generation on every invalidation, and a store whose pre-send sample
    no longer matches is dropped."""
    rb = RemoteBackend(addr=server.addr, cache_subjects={"w"})
    try:
        rb.put(("w", 5), 1.0)
        gen = rb._inv_gen
        result = rb._request("read", (("w", 5),))
        with rb._inv_lock:                 # what _recv_loop does on 'inv'
            rb._inv_gen += 1
        rb._cache_store(("w", 5), result, gen)
        assert ("w", 5) not in rb._cache   # invalidated mid-flight: dropped
        gen = rb._inv_gen
        result = rb._request("read", (("w", 5),))
        rb._cache_store(("w", 5), result, gen)
        assert ("w", 5) in rb._cache       # quiescent: stored
    finally:
        rb.close()


def test_cache_coherence_under_commit_race(server):
    """Hammer the commit cycle (delete + re-put by another client)
    against a caching reader: the reader must never observe the value
    going backwards — a regression would mean a stale entry was stored
    after its invalidation frame was drained and then served for the
    whole next version window."""
    reader = RemoteBackend(addr=server.addr, cache_subjects={"w"})
    writer = RemoteBackend(addr=server.addr, cache_subjects=())
    writer.put(("w", 0), 0)
    stop = threading.Event()

    def commit_loop():
        v = 0
        while not stop.is_set():
            v += 1
            writer.delete(("w", 0))
            writer.put(("w", 0), v)

    th = threading.Thread(target=commit_loop, daemon=True)
    th.start()
    last = -1
    try:
        deadline = time.monotonic() + 1.5
        while time.monotonic() < deadline:
            hit = reader.try_read(("w", 0))
            if hit is None:
                continue                   # between delete and re-put
            assert hit[1] >= last, (
                f"served stale cached value {hit[1]} after observing {last}")
            last = hit[1]
    finally:
        stop.set()
        th.join(3.0)
        reader.close()
        writer.close()
    assert last >= 0


def test_cache_never_serves_nonconcrete_patterns(server):
    rb = RemoteBackend(addr=server.addr, cache_subjects={"w"})
    try:
        rb.put(("w", 0), 1.0)
        rb.read(("w", 0))
        before = rb.round_trips
        rb.read(("w", ANY))               # wildcard: must round-trip
        assert rb.round_trips == before + 1
    finally:
        rb.close()


# ------------------------------------------------- restart / reconnection
def test_server_restart_errors_then_reconnects():
    srv = TSServer("sharded:2").start()
    host, port = srv.addr
    rb = RemoteBackend(addr=(host, port), cache_subjects=())
    rb.put(("w", 0), 1)
    srv.close()
    time.sleep(0.1)
    # Broken connection surfaces as RemoteSpaceError, not a hang.
    with pytest.raises(RemoteSpaceError):
        rb.read(("w", 0), timeout=1.0)
    # Server comes back on the same port: the next op reconnects.
    # (Rebinding immediately after close can briefly hit EADDRINUSE —
    # retry until the kernel releases the listening socket.)
    bind_deadline = time.monotonic() + 5.0
    while True:
        try:
            srv2 = TSServer("sharded:2", host=host, port=port).start()
            break
        except OSError:
            if time.monotonic() > bind_deadline:
                raise
            time.sleep(0.1)
    try:
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            try:
                rb.ping()
                break
            except RemoteSpaceError:
                time.sleep(0.05)
        assert rb.ping() == "pong"
        assert rb.reconnects >= 1
        # State lived in the dead server: gone. The client surface is
        # explicit about that (fresh store), not silently stale.
        assert rb.try_read(("w", 0)) is None
    finally:
        rb.close()
        srv2.close()


def test_dead_connection_unparks_server_waiters(server):
    """A waiter parked with ``timeout=None`` must not outlive its
    connection: when the client dies mid-blocking-take (the process
    fleet SIGKILLs workers), the server-side dispatch thread unparks
    within one ``WAITER_SLICE`` re-check instead of leaking in the
    hosted backend's condvar for the life of the run."""
    def wait_threads():
        return [t for t in threading.enumerate()
                if t.name.startswith("ts-wait-")]

    rb = RemoteBackend(addr=server.addr, cache_subjects=())
    errs = []

    def waiter():
        try:
            rb.get(("never-arrives", 0), timeout=None)
        except RemoteSpaceError as e:
            errs.append(e)

    th = threading.Thread(target=waiter, daemon=True)
    th.start()
    deadline = time.monotonic() + 5.0
    while time.monotonic() < deadline and not wait_threads():
        time.sleep(0.02)
    assert wait_threads(), "waiter never parked server-side"
    rb.close()                     # hard client death: FIN both ways
    th.join(5.0)
    assert errs, "client-side waiter did not fail on connection loss"
    deadline = time.monotonic() + 3 * WAITER_SLICE + 2.0
    while time.monotonic() < deadline and wait_threads():
        time.sleep(0.05)
    assert not wait_threads(), "server leaked parked waiter threads"


def test_pending_waiter_fails_fast_on_server_death():
    srv = TSServer("sharded:2").start()
    rb = RemoteBackend(addr=srv.addr, cache_subjects=())
    errs = []

    def waiter():
        try:
            rb.get(("never", 0), timeout=30.0)
        except (RemoteSpaceError, TSTimeout) as e:
            errs.append(e)

    th = threading.Thread(target=waiter, daemon=True)
    th.start()
    time.sleep(0.2)
    srv.close()
    th.join(5.0)               # NOT 30 — the death must fail the waiter
    rb.close()
    assert not th.is_alive()
    assert errs and isinstance(errs[0], RemoteSpaceError)


# ------------------------------------- server-side sanitizers (role/ctx)
def test_roles_transmitted_to_server_side_checked(server):
    """A checked stack on the SERVER must attribute remote ops to the
    client thread's role — the request carries it."""
    srv = TSServer("checked+sharded:2").start()
    try:
        rb = RemoteBackend(addr=srv.addr, cache_subjects=())
        checked = srv.backend
        from repro.core.space.schema import KeySchema
        from repro.core.space.api import Key  # noqa: F401
        checked.registry.register(KeySchema(
            subject="guarded", fields=(), producers=frozenset({"manager"}),
            consumers=frozenset({"manager"}), deleters=frozenset({"manager"}),
            lifecycle="persistent"))
        with role("handler"):
            rb.put(("guarded",), 1)          # wrong role → recorded
        with role("manager"):
            rb.put(("guarded",), 2)          # right role → clean
        report = checked.protocol_report()
        assert report["violations"] == 1
        assert "handler" in report["violation_samples"][0]
        rb.close()
    finally:
        srv.close()


# ----------------------------------------------- spec / facade integration
def test_make_backend_remote_spec_spawns_private_server():
    b = make_backend("remote+sharded:2")
    try:
        assert isinstance(b, RemoteBackend)
        b.put(("w", 0), np.arange(8.0))
        assert b.read(("w", 0))[1][5] == 5.0
    finally:
        b.close()


def test_make_backend_remote_client_side_wrappers():
    from repro.core.space import InstrumentedBackend
    b = make_backend("instrumented+remote+sharded:2")
    try:
        assert isinstance(b, InstrumentedBackend)
        assert isinstance(b.inner, RemoteBackend)
        assert b.inner.server_spec == "sharded:2"
    finally:
        b.inner.close()


def test_remote_spec_rejects_recursion():
    with pytest.raises(ValueError):
        TSServer("remote+sharded")


# --------------------------------------- numpy canonicalization (sat. 1)
def test_numpy_scalar_key_fields_canonicalized():
    assert canonicalize_key(("loss", 1, np.int64(3))) == ("loss", 1, 3)
    assert type(canonicalize_key(("x", np.float32(0.5)))[1]) is float
    same = ("plain", 1, "s")
    assert canonicalize_key(same) is same          # fast path: no copy


def test_facade_canonicalizes_numpy_aliased_keys():
    """The regression the satellite names: ``("loss", d, np.int64(s))``
    and ``("loss", d, s)`` must be ONE key through the facade — puts
    alias, reads alias, deletes alias."""
    ts = TupleSpace(backend="local")
    ts.put(("loss", 0, np.int64(3)), 0.25)
    assert ts.count(("loss", 0, 3)) == 1
    hit = ts.try_read(("loss", 0, np.int64(3)))
    assert hit is not None and type(hit[0][2]) is int
    ts.put(("loss", 0, 3), 0.5)                    # overwrite, not alias
    assert ts.count(("loss", ANY, ANY)) == 1
    assert ts.delete(("loss", np.int64(0), 3)) == 1


def test_facade_canonicalizes_put_many_and_batch_ops():
    ts = TupleSpace(backend="local")
    ts.put_many([(("task", np.int32(i)), i) for i in range(4)])
    got = ts.take_batch(("task", ANY), 4, timeout=1.0)
    assert [type(k[1]) for k, _ in got] == [int] * 4
