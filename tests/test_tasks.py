"""Task partitioning invariants (paper §5.2) — property-based, now
running through the PR-3 op registry (cost models and split rules are
per-op, looked up by the open op *name* instead of a closed enum).

The partition must (a) respect the size cap, (b) exactly tile the original
task's (input × output) rectangle with disjoint pieces, (c) follow the
4-way / 2-way split rules, (d) round-trip the declarative wire format."""

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (GLOBAL_OPS, LayerSpec, TaskDesc, UnknownOp,
                        partition, prototype_tasks)
from repro.programs.mlp import (ACTIVATION, BACKWARD, FORWARD, LOSS, UPDATE,
                                stage_order)

MLP_OPS = [FORWARD, ACTIVATION, LOSS, BACKWARD, UPDATE]

dims = st.sampled_from([1, 2, 4, 8, 16, 32, 64, 128, 256])
caps = st.sampled_from([16.0, 64.0, 256.0, 1024.0])


@given(dims, dims, caps)
@settings(max_examples=200, deadline=None)
def test_forward_partition_tiles_exactly(m, n, cap):
    t = TaskDesc(FORWARD, 0, 0, 0, 0, m, 0, n)
    pieces = partition(t, cap)
    # size cap respected whenever splitting is possible
    for p in pieces:
        assert GLOBAL_OPS.cost(p) <= cap or (p.m <= 1 and p.n <= 1)
    # exact disjoint cover of the m×n rectangle
    cells = set()
    for p in pieces:
        for i in range(p.in_lo, p.in_hi):
            for j in range(p.out_lo, p.out_hi):
                assert (i, j) not in cells, "overlap"
                cells.add((i, j))
    assert len(cells) == m * n


@given(dims, caps)
@settings(max_examples=100, deadline=None)
def test_1d_partition_covers(n, cap):
    t = TaskDesc(ACTIVATION, 0, 0, 0, 0, 0, 0, n)
    pieces = partition(t, cap)
    covered = sorted((p.out_lo, p.out_hi) for p in pieces)
    cur = 0
    for lo, hi in covered:
        assert lo == cur
        cur = hi
    assert cur == n


def test_forward_splits_four_way():
    t = TaskDesc(FORWARD, 0, 0, 0, 0, 8, 0, 8)
    kids = GLOBAL_OPS.split(t)
    assert len(kids) == 4        # paper: "split into FOUR smaller tasks"
    assert {(k.in_lo, k.in_hi, k.out_lo, k.out_hi) for k in kids} == {
        (0, 4, 0, 4), (0, 4, 4, 8), (4, 8, 0, 4), (4, 8, 4, 8)}


def test_update_splits_two_way():
    t = TaskDesc(UPDATE, 0, 0, 0, 0, 8, 0, 8)
    kids = GLOBAL_OPS.split(t)
    assert len(kids) == 2        # "each updating m/2 parameters"


def test_loss_costs_more_per_element():
    loss = TaskDesc(LOSS, 0, 0, 0, 0, 0, 0, 16)
    act = TaskDesc(ACTIVATION, 0, 0, 0, 0, 0, 0, 16)
    # §5.2 "proportionally larger size"
    assert GLOBAL_OPS.cost(loss) > GLOBAL_OPS.cost(act)


def test_unregistered_op_raises():
    t = TaskDesc("nosuchop", 0, 0, 0)
    with pytest.raises(UnknownOp):
        GLOBAL_OPS.cost(t)


@given(st.sampled_from(MLP_OPS), dims, dims)
@settings(max_examples=50, deadline=None)
def test_wire_roundtrip(op, m, n):
    t = TaskDesc(op, 3, 7, 11, 0, m, 0, n, task_id="x1")
    assert TaskDesc.from_wire(t.to_wire()) == t
    assert isinstance(TaskDesc.from_wire(t.to_wire()).op, str)


def test_paper_model_task_census():
    """Paper §6: N=4⁴ model, cap=4⁴ — layer-1 forward must partition into
    256 tasks of 16×16."""
    stages = prototype_tasks([LayerSpec(256, 256), LayerSpec(256, 1)], 0, 0)
    fwd0 = partition(stages["fwd_0"][0], 256.0)
    assert len(fwd0) == 256
    assert all(p.m == 16 and p.n == 16 for p in fwd0)
    fwd1 = partition(stages["fwd_1"][0], 256.0)
    assert len(fwd1) == 1        # 256×1 is exactly at cap


def test_stage_order_dependencies():
    order = stage_order(3)
    assert order.index("fwd_0") < order.index("act_0") < order.index("fwd_1")
    assert order.index("loss") < order.index("bwd_2") < order.index("bwd_0")
    assert order.index("bwd_0") < order.index("upd_0")
