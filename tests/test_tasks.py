"""Task partitioning invariants (paper §5.2) — property-based.

The partition must (a) respect the size cap, (b) exactly tile the original
task's (input × output) rectangle with disjoint pieces, (c) follow the
4-way / 2-way split rules, (d) round-trip the declarative wire format."""

from _hypothesis_compat import given, settings, st

from repro.core import LayerSpec, TaskDesc, TaskKind, partition, prototype_tasks
from repro.core.tasks import stage_order

dims = st.sampled_from([1, 2, 4, 8, 16, 32, 64, 128, 256])
caps = st.sampled_from([16.0, 64.0, 256.0, 1024.0])


@given(dims, dims, caps)
@settings(max_examples=200, deadline=None)
def test_forward_partition_tiles_exactly(m, n, cap):
    t = TaskDesc(TaskKind.FORWARD, 0, 0, 0, 0, m, 0, n)
    pieces = partition(t, cap)
    # size cap respected whenever splitting is possible
    for p in pieces:
        assert p.cost() <= cap or (p.m <= 1 and p.n <= 1)
    # exact disjoint cover of the m×n rectangle
    cells = set()
    for p in pieces:
        for i in range(p.in_lo, p.in_hi):
            for j in range(p.out_lo, p.out_hi):
                assert (i, j) not in cells, "overlap"
                cells.add((i, j))
    assert len(cells) == m * n


@given(dims, caps)
@settings(max_examples=100, deadline=None)
def test_1d_partition_covers(n, cap):
    t = TaskDesc(TaskKind.ACTIVATION, 0, 0, 0, 0, 0, 0, n)
    pieces = partition(t, cap)
    covered = sorted((p.out_lo, p.out_hi) for p in pieces)
    cur = 0
    for lo, hi in covered:
        assert lo == cur
        cur = hi
    assert cur == n


def test_forward_splits_four_way():
    t = TaskDesc(TaskKind.FORWARD, 0, 0, 0, 0, 8, 0, 8)
    kids = t.split()
    assert len(kids) == 4        # paper: "split into FOUR smaller tasks"
    assert {(k.in_lo, k.in_hi, k.out_lo, k.out_hi) for k in kids} == {
        (0, 4, 0, 4), (0, 4, 4, 8), (4, 8, 0, 4), (4, 8, 4, 8)}


def test_update_splits_two_way():
    t = TaskDesc(TaskKind.UPDATE, 0, 0, 0, 0, 8, 0, 8)
    kids = t.split()
    assert len(kids) == 2        # "each updating m/2 parameters"


def test_loss_costs_more_per_element():
    loss = TaskDesc(TaskKind.LOSS, 0, 0, 0, 0, 0, 0, 16)
    act = TaskDesc(TaskKind.ACTIVATION, 0, 0, 0, 0, 0, 0, 16)
    assert loss.cost() > act.cost()   # §5.2 "proportionally larger size"


@given(st.sampled_from(list(TaskKind)), dims, dims)
@settings(max_examples=50, deadline=None)
def test_wire_roundtrip(kind, m, n):
    t = TaskDesc(kind, 3, 7, 11, 0, m, 0, n, task_id="x1")
    assert TaskDesc.from_wire(t.to_wire()) == t


def test_paper_model_task_census():
    """Paper §6: N=4⁴ model, cap=4⁴ — layer-1 forward must partition into
    256 tasks of 16×16."""
    stages = prototype_tasks([LayerSpec(256, 256), LayerSpec(256, 1)], 0, 0)
    fwd0 = partition(stages["fwd_0"][0], 256.0)
    assert len(fwd0) == 256
    assert all(p.m == 16 and p.n == 16 for p in fwd0)
    fwd1 = partition(stages["fwd_1"][0], 256.0)
    assert len(fwd1) == 1        # 256×1 is exactly at cap


def test_stage_order_dependencies():
    order = stage_order(3)
    assert order.index("fwd_0") < order.index("act_0") < order.index("fwd_1")
    assert order.index("loss") < order.index("bwd_2") < order.index("bwd_0")
    assert order.index("bwd_0") < order.index("upd_0")
