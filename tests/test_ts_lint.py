"""Static tuple-space lint pass (PR 6): the sources must resolve clean
against the key-schema registry, and every seeded-violation fixture must
be flagged with exactly the kind it seeds.
"""

import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from tools.ts_lint import (DOC_END, DOC_START, doc_table,  # noqa: E402
                           lint_paths, main)

FIXTURES = REPO / "tools" / "ts_lint_fixtures"

#: fixture file -> the single violation kind it seeds
EXPECTED = {
    "fx_unknown_subject.py": "unknown-subject",
    "fx_arity_mismatch.py": "arity-mismatch",
    "fx_wildcard_in_put.py": "wildcard-in-put",
    "fx_role_violation.py": "role-violation",
    "fx_widened_delete.py": "widened-delete",
    "fx_bad_literal_type.py": "bad-literal-type",
}


def test_sources_lint_clean():
    findings = lint_paths([REPO / "src" / "repro"])
    assert findings == [], "\n".join(str(f) for f in findings)


def test_every_fixture_flagged_with_expected_kind():
    findings = lint_paths([FIXTURES])
    by_file = {}
    for f in findings:
        by_file.setdefault(Path(f.path).name, []).append(f)
    assert set(by_file) == set(EXPECTED)
    for name, kind in EXPECTED.items():
        kinds = [f.kind for f in by_file[name]]
        assert kinds == [kind], f"{name}: {kinds}"


def test_cli_exit_codes():
    assert main([str(REPO / "src" / "repro")]) == 0
    assert main([str(FIXTURES)]) == 1


def test_doc_table_covers_control_and_program_planes():
    table = doc_table()
    for subject in ("task", "done", "mstate", "fpart", "efwd",
                    "params", "gpart"):
        assert f'"{subject}"' in table
    for lifecycle in ("persistent", "round_scoped", "taken_once"):
        assert lifecycle in table


def test_readme_table_is_current():
    readme = REPO / "README.md"
    text = readme.read_text()
    assert DOC_START in text and DOC_END in text
    assert main(["--check-doc", str(readme)]) == 0
