"""Static tuple-space lint pass (PR 6): the sources must resolve clean
against the key-schema registry, and every seeded-violation fixture must
be flagged with exactly the kind it seeds.
"""

import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from tools.ts_lint import (DOC_END, DOC_START, doc_table,  # noqa: E402
                           lint_paths, main, resolution_stats)

FIXTURES = REPO / "tools" / "ts_lint_fixtures"

#: fixture file -> the single violation kind it seeds
EXPECTED = {
    "fx_unknown_subject.py": "unknown-subject",
    "fx_arity_mismatch.py": "arity-mismatch",
    "fx_wildcard_in_put.py": "wildcard-in-put",
    "fx_role_violation.py": "role-violation",
    "fx_widened_delete.py": "widened-delete",
    "fx_bad_literal_type.py": "bad-literal-type",
}


def test_sources_lint_clean():
    findings = lint_paths([REPO / "src" / "repro"])
    assert findings == [], "\n".join(str(f) for f in findings)


def test_every_fixture_flagged_with_expected_kind():
    findings = lint_paths([FIXTURES])
    by_file = {}
    for f in findings:
        by_file.setdefault(Path(f.path).name, []).append(f)
    assert set(by_file) == set(EXPECTED)
    for name, kind in EXPECTED.items():
        kinds = [f.kind for f in by_file[name]]
        assert kinds == [kind], f"{name}: {kinds}"


def test_cli_exit_codes():
    assert main([str(REPO / "src" / "repro")]) == 0
    assert main([str(FIXTURES)]) == 1


def test_doc_table_covers_control_and_program_planes():
    table = doc_table()
    for subject in ("task", "done", "mstate", "fpart", "efwd",
                    "params", "gpart"):
        assert f'"{subject}"' in table
    for lifecycle in ("persistent", "round_scoped", "taken_once"):
        assert lifecycle in table


def test_readme_table_is_current():
    readme = REPO / "README.md"
    text = readme.read_text()
    assert DOC_START in text and DOC_END in text
    assert main(["--check-doc", str(readme)]) == 0


# ----------------------------------------------- constant folding (PR 8)
def test_constant_folding_only_increases_resolved_sites():
    """Folding module constants / str concatenation into key subjects
    must never lose a site the plain resolver handled."""
    on = resolution_stats([REPO / "src" / "repro"], fold=True)
    off = resolution_stats([REPO / "src" / "repro"], fold=False)
    assert on["sites"] == off["sites"]
    assert on["resolved"] >= off["resolved"]


def test_constant_folding_resolves_constant_subjects(tmp_path):
    """A subject spelled as a module-level UPPER_CASE constant or a
    f-string-free str concatenation resolves only with folding."""
    src = tmp_path / "folded.py"
    src.write_text(
        'CURSOR_SUBJECT = "mstate"\n'
        'PREFIX = "ms"\n'
        'COMBINED = PREFIX + "tate"\n'
        "def probe(ts):\n"
        "    ts.try_read((CURSOR_SUBJECT, 'cursor'))\n"
        "    ts.try_read((COMBINED, 'cursor'))\n"
        "    ts.try_read(('ms' + 'tate', 'cursor'))\n")
    on = resolution_stats([src], fold=True)
    off = resolution_stats([src], fold=False)
    assert on["sites"] == off["sites"] == 3
    assert off["resolved"] == 1        # literal 'ms' + 'tate' needs no env
    assert on["resolved"] == 3         # constants fold only with the env
    # the folded subjects resolve against the real schema: lint-clean
    assert lint_paths([src]) == []


def test_folded_subjects_are_schema_checked(tmp_path):
    """Folding feeds the same checks literal subjects get — an
    arity-mismatch behind a constant is now caught."""
    src = tmp_path / "folded_bad.py"
    src.write_text(
        'CURSOR_SUBJECT = "mstate"\n'
        "def probe(ts):\n"
        "    ts.try_read((CURSOR_SUBJECT, 'cursor', 'extra'))\n")
    findings = lint_paths([src])
    assert [f.kind for f in findings] == ["arity-mismatch"]
