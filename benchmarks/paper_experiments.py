"""The paper's three experiments (§6) — Figures 1-4 reproduced as CSVs.

``scale="ci"`` runs a compressed variant (small N, few samples, compressed
fault intervals) for the benchmark harness; ``scale="paper"`` runs the
paper's exact setup (N=4⁴ model, task cap 4⁴, pouch 100, 4 handlers,
100 samples × 2 epochs / 20 samples for exp2-3)."""

from __future__ import annotations

import os

import numpy as np

from repro.configs import paper_mlp
from repro.core import ACANCloud, CloudConfig, FaultPlan, LayerSpec

OUT = os.path.join(os.path.dirname(__file__), "out")


def _ci_cfg(**kw):
    base = dict(layers=[LayerSpec(64, 64), LayerSpec(64, 1)],
                n_handlers=4, epochs=2, n_samples=16, task_cap=256.0,
                pouch_size=100, lr=0.01, time_scale=1e-6,
                initial_timeout=0.12, wall_limit=240.0, seed=0)
    base.update(kw)
    return CloudConfig(**base)


def _write_csv(name: str, header: str, rows) -> str:
    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, name)
    with open(path, "w") as f:
        f.write(header + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return path


def exp1_feasibility(scale: str = "ci") -> dict:
    """Fig. 1: MSE loss under the ACAN runtime, stable conditions."""
    cfg = (paper_mlp.feasibility_config()
           if scale == "paper" else _ci_cfg(fault_plan=FaultPlan(interval=1e9)))
    res = ACANCloud(cfg).run()
    losses = [l for _, l in res.loss_history]
    _write_csv("exp1_loss.csv", "step,mse",
               [(s, l) for s, l in res.loss_history])
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    return {"steps": len(losses), "first_mse": first, "last_mse": last,
            "decreased": bool(last < first), "wall": res.wallclock,
            "pouches": res.pouches}


def exp2_adaptability(scale: str = "ci") -> dict:
    """Fig. 2: timeout vs aggregate handler power (speeds 1:5:10 re-drawn
    each interval) — the claim is an inverse relation."""
    cfg = (paper_mlp.adaptability_config()
           if scale == "paper" else
           _ci_cfg(epochs=1,
                   fault_plan=FaultPlan(interval=0.15,
                                        speed_levels=(1.0, 5.0, 10.0),
                                        p_speed_change=1.0, seed=3)))
    res = ACANCloud(cfg).run()
    t = np.array([x[1] for x in res.timeout_history])
    p = np.array([x[2] for x in res.timeout_history])
    m = p > 0
    r = float(np.corrcoef(t[m], p[m])[0, 1]) if m.sum() > 3 else float("nan")
    _write_csv("exp2_timeout_power.csv", "wallclock,timeout,power",
               res.timeout_history)
    return {"pouches": res.pouches, "corr_timeout_power": r,
            "speed_changes": res.speed_changes, "inverse": bool(r < 0)}


def exp3_robustness(scale: str = "ci") -> dict:
    """Fig. 3+4: Manager AND all Handlers crash each interval (p=1.0);
    training must still converge, inverse relation must persist."""
    cfg = (paper_mlp.robustness_config()
           if scale == "paper" else
           _ci_cfg(fault_plan=FaultPlan(interval=0.25,
                                        speed_levels=(1.0, 5.0, 10.0),
                                        p_speed_change=1.0,
                                        p_handler_crash=1.0,
                                        p_manager_crash=1.0, seed=1)))
    res = ACANCloud(cfg).run()
    losses = [l for _, l in res.loss_history]
    t = np.array([x[1] for x in res.timeout_history])
    p = np.array([x[2] for x in res.timeout_history])
    m = p > 0
    r = float(np.corrcoef(t[m], p[m])[0, 1]) if m.sum() > 3 else float("nan")
    _write_csv("exp3_loss.csv", "step,mse",
               [(s, l) for s, l in res.loss_history])
    _write_csv("exp3_timeout_power.csv", "wallclock,timeout,power",
               res.timeout_history)
    return {"steps": len(losses),
            "completed": bool(len(losses) == cfg.epochs * cfg.n_samples),
            "first_mse": float(np.mean(losses[:5])),
            "last_mse": float(np.mean(losses[-5:])),
            "manager_revivals": res.manager_revivals,
            "handler_revivals": res.handler_revivals,
            "corr_timeout_power": r, "ledger_ok": res.ledger_ok}


def acan_overhead(_scale: str = "ci") -> dict:
    """Paper §8 claims TS-mediated communication costs ~2× direct
    program-to-program. Measure: same training, ACAN runtime vs plain
    numpy loop. (One size fits both scales — the overhead ratio is what
    matters, not the workload size.)"""
    import time
    from tests.test_system import _numpy_reference_training  # reuse oracle
    layers = [LayerSpec(32, 32), LayerSpec(32, 1)]
    cfg = CloudConfig(layers=layers, n_handlers=4, epochs=1, n_samples=12,
                      task_cap=64.0, pouch_size=100, lr=0.05,
                      time_scale=0.0,          # no simulated compute delay
                      initial_timeout=0.05,
                      fault_plan=FaultPlan(interval=1e9), seed=0,
                      wall_limit=120.0)
    res = ACANCloud(cfg).run()
    from repro.core import make_teacher_data
    X, Y = make_teacher_data(layers, 12, 0)
    t0 = time.perf_counter()
    _numpy_reference_training(layers, X, Y, 0.05, 1)
    direct = time.perf_counter() - t0
    return {"acan_wall": res.wallclock, "direct_wall": direct,
            "overhead_x": res.wallclock / max(direct, 1e-9),
            "ts_ops": res.ts_stats["puts"] + res.ts_stats["takes"]
            + res.ts_stats["reads"]}


def ablation_task_pouch(_scale: str = "ci") -> list[dict]:
    """Beyond-paper ablation: the paper names task size / pouch size /
    timeout as the three tuning knobs (§4) but only sweeps timeout.
    Sweep (task_cap × pouch) on the feasibility workload; report wall
    clock, pouch rounds, and TS traffic — the GSS tradeoff curve.
    (One size fits both scales — the sweep grid is the point.)"""
    rows = []
    for cap in (64.0, 256.0, 1024.0):
        for pouch in (25, 400):
            cfg = _ci_cfg(epochs=1, n_samples=8, task_cap=cap,
                          pouch_size=pouch,
                          fault_plan=FaultPlan(interval=1e9))
            res = ACANCloud(cfg).run()
            losses = [l for _, l in res.loss_history]
            rows.append({"task_cap": cap, "pouch": pouch,
                         "wall": round(res.wallclock, 2),
                         "pouches": res.pouches,
                         "ts_ops": res.ts_stats["puts"] + res.ts_stats["takes"],
                         "final_mse": round(float(np.mean(losses[-3:])), 4)})
    return rows
