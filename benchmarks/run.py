"""Benchmark harness — one entry per paper table/figure plus framework
benches. Prints ``name,us_per_call,derived`` CSV rows and persists the
same rows machine-readably to ``runs/bench/BENCH_<n>.json`` (next free
``n`` — one immutable artifact per invocation, so regressions can be
diffed across runs without scraping stdout; each row records the
metric, the raw derived string, and the parsed ``pass=`` gate verdict
where the row carries one).

    PYTHONPATH=src python -m benchmarks.run [--paper-scale]

Paper experiments (§6, Figures 1-4) run at CI scale by default (compressed
intervals, smaller N — structure preserved: speed ratios 1:5:10, crash
probability 1.0); ``--paper-scale`` runs the exact paper setup (slower).
The roofline rows summarise the multi-pod dry-run artifacts if present
(see launch/dryrun.py)."""

from __future__ import annotations

import json
import os
import re
import sys
import time

#: Where the per-invocation JSON artifacts land (repo-relative).
BENCH_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "runs", "bench")


def _row_record(us: float, derived: str) -> dict:
    """One row's machine-readable record. ``gate_pass`` is the parsed
    ``pass=True/False`` verdict for gate rows, None for plain metrics."""
    m = re.search(r"\bpass=(True|False)\b", derived)
    return {"us_per_call": round(us, 1), "derived": derived,
            "gate_pass": None if m is None else m.group(1) == "True"}


def write_bench_json(rows: list[tuple[str, float, str]],
                     scale: str, out_dir: str = BENCH_DIR) -> str:
    """Persist rows to the next free ``BENCH_<n>.json`` and return its
    path. ``n`` is one past the highest existing artifact number, so
    artifacts are append-only across invocations."""
    os.makedirs(out_dir, exist_ok=True)
    taken = []
    for fn in os.listdir(out_dir):
        m = re.fullmatch(r"BENCH_(\d+)\.json", fn)
        if m is not None:
            taken.append(int(m.group(1)))
    path = os.path.join(out_dir, f"BENCH_{max(taken, default=0) + 1}.json")
    doc = {
        "scale": scale,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "rows": {name: _row_record(us, derived)
                 for name, us, derived in rows},
        "gates_passed": all(
            r["gate_pass"] is not False
            for r in (_row_record(us, d) for _, us, d in rows)),
    }
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return path


def main() -> None:
    paper_scale = "--paper-scale" in sys.argv
    scale = "paper" if paper_scale else "ci"
    rows: list[tuple[str, float, str]] = []

    from benchmarks import paper_experiments as PE

    t0 = time.perf_counter()
    r1 = PE.exp1_feasibility(scale)
    rows.append(("exp1_feasibility_fig1", (time.perf_counter() - t0) * 1e6,
                 f"mse {r1['first_mse']:.3f}->{r1['last_mse']:.3f} "
                 f"decreased={r1['decreased']} pouches={r1['pouches']}"))

    t0 = time.perf_counter()
    r2 = PE.exp2_adaptability(scale)
    rows.append(("exp2_adaptability_fig2", (time.perf_counter() - t0) * 1e6,
                 f"corr(timeout,power)={r2['corr_timeout_power']:.3f} "
                 f"inverse={r2['inverse']} pouches={r2['pouches']}"))

    t0 = time.perf_counter()
    r3 = PE.exp3_robustness(scale)
    rows.append(("exp3_robustness_fig3_4", (time.perf_counter() - t0) * 1e6,
                 f"completed={r3['completed']} "
                 f"mse {r3['first_mse']:.3f}->{r3['last_mse']:.3f} "
                 f"mgr_revive={r3['manager_revivals']} "
                 f"hdl_revive={r3['handler_revivals']} "
                 f"corr={r3['corr_timeout_power']:.3f}"))

    t0 = time.perf_counter()
    r4 = PE.acan_overhead(scale)
    rows.append(("acan_vs_direct_overhead_s8", (time.perf_counter() - t0) * 1e6,
                 f"overhead={r4['overhead_x']:.1f}x ts_ops={r4['ts_ops']}"))

    t0 = time.perf_counter()
    for row in PE.ablation_task_pouch(scale):
        rows.append((f"ablation_cap{int(row['task_cap'])}_pouch{row['pouch']}",
                     row["wall"] * 1e6,
                     f"pouches={row['pouches']} ts_ops={row['ts_ops']} "
                     f"mse={row['final_mse']}"))

    # Control-plane scheduling rows (PR 2/4): poll vs event on the §6.1
    # workload (including the ops-per-pouch gate ratio) plus the adaptive
    # pouch-size row against the fixed §6 baseline.
    from benchmarks import sched_bench as SB
    rows.extend(SB.bench_rows(smoke=not paper_scale))

    # Remote tuple-space rows (PR 10): pipelined contention, pouch
    # batching (2 round-trips per put_many/take_batch pair), and the
    # read-through cache — each against a private server process.
    from benchmarks import ts_bench as TB
    rows.extend(TB.bench_rows(smoke=not paper_scale))

    # WorkloadProgram rows (PR 3/4): the paper MLP, the non-regular MoE
    # routing program (with and without an exp3-style fault plan), the
    # MLP+MoE multi-tenant co-residency gate, and — at paper scale — the
    # JAX-SGD program.
    from benchmarks import program_bench as PB
    rows.extend(PB.bench_rows(smoke=not paper_scale,
                              include_jax=paper_scale))

    # Crash-point sweep row (PR 9): arm the deterministic crash backend
    # at registry sites (sampled per protection class at CI scale, the
    # full Manager/Handler/executor site list at paper scale) and gate
    # recovery on completion + bit-identical trajectories + zero
    # leaks/races + role revival.
    import tools.crash_sweep as CS
    rows.extend(CS.bench_rows(smoke=not paper_scale))

    from benchmarks import kernel_bench as KB
    rows.extend(KB.bench_tuplespace())
    rows.extend(KB.bench_tile_matmul())
    rows.extend(KB.bench_attention())
    rows.extend(KB.bench_ssd())

    # Roofline summary from dry-run artifacts (if the sweep has been run)
    try:
        from benchmarks.roofline import load_cells, roofline_fraction, summary
        cells = load_cells()
        if cells:
            s = summary(cells)
            rows.append(("dryrun_roofline_cells", 0.0,
                         f"n={s['cells']} dominant={s['dominant_histogram']}"))
            for c in cells:
                rows.append((f"roofline_{c['arch']}_{c['shape']}", 0.0,
                             f"dom={c['dominant'].replace('_s','')} "
                             f"frac={roofline_fraction(c):.3f}"))
    except Exception as e:              # noqa: BLE001
        rows.append(("dryrun_roofline_cells", 0.0, f"unavailable: {e}"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    path = write_bench_json(rows, scale)
    print(f"# wrote {os.path.relpath(path)}", file=sys.stderr)


if __name__ == "__main__":
    main()
