"""Roofline report generator: reads experiments/dryrun/*.json (written by
launch/dryrun.py) and emits the EXPERIMENTS.md §Roofline table."""

from __future__ import annotations

import glob
import json
import os

ARCH_ORDER = [
    "smollm_360m", "h2o_danube_1_8b", "command_r_plus_104b", "gemma3_12b",
    "mamba2_2_7b", "jamba_1_5_large_398b", "internvl2_76b",
    "deepseek_v2_lite_16b", "qwen2_moe_a2_7b", "musicgen_medium",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_cells(dirpath: str = "experiments/dryrun", mesh: str = "single",
               tag: str = "") -> list[dict]:
    cells = []
    for path in glob.glob(os.path.join(dirpath, "*.json")):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        cell_tag = parts[2].split("_", 1)[1] if "_" in parts[2] else ""
        if parts[2].split("_")[0] != mesh or cell_tag != tag:
            continue
        with open(path) as f:
            cells.append(json.load(f))
    key = lambda c: (ARCH_ORDER.index(c["arch"]) if c["arch"] in ARCH_ORDER
                     else 99, SHAPE_ORDER.index(c["shape"]))
    return sorted(cells, key=key)


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def roofline_fraction(c: dict) -> float:
    """Achievable MFU proxy: model_flops_time / max(all terms).
    model_flops_time = useful flops at peak; the bound is the slowest
    resource."""
    t = c["terms"]
    bound = max(t.values())
    if bound <= 0:
        return 0.0
    useful_time = c["model_flops_per_dev"] / 197e12
    return useful_time / bound


def markdown_table(cells: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| peak mem/dev | useful/HLO flops | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|")
    rows = [hdr]
    for c in cells:
        t = c["terms"]
        rows.append(
            f"| {c['arch']} | {c['shape']} "
            f"| {t['compute_s']:.2e} | {t['memory_s']:.2e} "
            f"| {t['collective_s']:.2e} "
            f"| {c['dominant'].replace('_s', '')} "
            f"| {fmt_bytes(c['memory'].get('peak_memory_in_bytes', 0))} "
            f"| {c['useful_flops_ratio']:.3f} "
            f"| {roofline_fraction(c):.3f} |")
    return "\n".join(rows)


def summary(cells) -> dict:
    doms = {}
    for c in cells:
        doms[c["dominant"]] = doms.get(c["dominant"], 0) + 1
    worst = min(cells, key=roofline_fraction) if cells else None
    most_coll = max(cells, key=lambda c: c["terms"]["collective_s"]
                    / max(max(c["terms"].values()), 1e-30)) if cells else None
    return {"cells": len(cells), "dominant_histogram": doms,
            "worst_roofline": (worst["arch"], worst["shape"],
                               round(roofline_fraction(worst), 4)) if worst else None,
            "most_collective_bound": (most_coll["arch"], most_coll["shape"])
            if most_coll else None}


if __name__ == "__main__":
    cells = load_cells()
    print(markdown_table(cells))
    print()
    print(json.dumps(summary(cells), indent=1))
