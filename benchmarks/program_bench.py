"""WorkloadProgram benchmark — three workloads, one control plane (PR 3).

    PYTHONPATH=src python benchmarks/program_bench.py \
        [--smoke] [--backend B] [--programs mlp,moe,moe_faults,jax]

Runs each program through the *same* Manager/Handler plane and reports
wallclock, TS traffic, pouch rounds, and the loss trajectory ends:

- ``mlp``        — the paper's §6.1 workload (regular, 5 MLP ops);
- ``moe``        — the non-regular MoE routing program: data-dependent
                   per-expert task sizes (min/max cost spread reported);
- ``moe_faults`` — the MoE program under an **exp3-style fault plan**
                   (Manager AND all Handlers crash each interval with
                   p=1.0, speeds 1:5:10 re-drawn) — the non-regular
                   robustness gate;
- ``jax``        — the JAX-SGD program (reduced smollm) with 25%
                   per-task handler crashes;
- ``multi``      — MLP + MoE **co-resident on one tuple space** (each in
                   its own namespace) under a shared handler fleet and an
                   exp3-style p=1.0 fault plan — the multi-tenant gate.

Acceptance (exit code): every selected program's loss must decrease,
``moe`` must exhibit irregular (non-uniform) expert task costs,
``moe_faults`` must complete all rounds with ≥ 1 manager revival and
≥ 1 handler revival, and ``multi`` must complete both tenants with ≥ 1
manager revival, ≥ 1 handler revival, and **zero cross-namespace task
deletions** (no widened-subject deletes, nothing removed under an
unscoped task subject — InstrumentedBackend delete accounting).

Every leg additionally runs under the ``CheckedBackend`` protocol
sanitizer (PR 6) and gates on **zero schema/role violations and zero
tuple leaks** at shutdown. A ``raced+...`` backend spec (the PR 8 CI
leg) further widens the frontier to 8 in-flight stages with the
cost-model autotune on and gates on an **empty happens-before race
report** from the ``RacedBackend`` sanitizer.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core import (ACANCloud, CloudConfig, FaultPlan, GLOBAL_OPS,  # noqa: E402
                        LayerSpec, MLPProgram, MoERoutingProgram)

DEFAULT_PROGRAMS = "mlp,moe,moe_faults,jax,multi"


def _ts_ops(res) -> int:
    s = res.ts_stats
    return s.get("puts", 0) + s.get("takes", 0) + s.get("reads", 0)


def _checked(spec: str | None) -> str:
    """Stack the protocol sanitizer onto ``spec`` (idempotent)."""
    inner = spec or os.environ.get("REPRO_TS_BACKEND", "") or "local"
    return inner if "checked" in inner else f"checked+{inner}"


def _ts_clean(res) -> bool:
    """Zero protocol violations, zero tuple leaks (CheckedBackend) and an
    empty happens-before race report (RacedBackend, PR 8 — trivially
    empty when the spec does not stack ``raced``)."""
    return (res.ts_violations == 0 and not res.ts_leaks
            and not getattr(res, "race_report", []))


def _race_kwargs(spec: str | None) -> dict:
    """Config overrides for the raced CI leg: widen the frontier to 8 and
    turn the cost-model autotune on, so the happens-before sanitizer
    watches real stage overlap rather than a serialized schedule."""
    inner = spec or os.environ.get("REPRO_TS_BACKEND", "") or "local"
    if "raced" not in inner:
        return {}
    return {"max_inflight_stages": 8, "autotune": True}


def run_mlp(smoke: bool, backend: str | None) -> dict:
    # The exp1 CI geometry (SGD bs=1 is noisy — single epochs over few
    # samples do not give a stable first/last comparison).
    epochs, n_samples = (2, 16) if smoke else (2, 100)
    cfg = CloudConfig(layers=[LayerSpec(64, 64), LayerSpec(64, 1)],
                      n_handlers=4, epochs=epochs, n_samples=n_samples,
                      task_cap=256.0, pouch_size=100, lr=0.01,
                      time_scale=1e-6, initial_timeout=0.12,
                      fault_plan=FaultPlan(interval=1e9), seed=0,
                      wall_limit=240.0, ts_backend=_checked(backend),
                      **_race_kwargs(backend))
    res = ACANCloud(cfg).run()
    losses = [l for _, l in res.loss_history]
    half = len(losses) // 2
    return {"name": "program_mlp", "wall": res.wallclock,
            "ts_ops": _ts_ops(res), "pouches": res.pouches,
            "first": float(np.mean(losses[:half])),
            "last": float(np.mean(losses[half:])),
            "completed": len(losses) == epochs * n_samples,
            "races": len(res.race_report),
            "ts_clean": _ts_clean(res),
            "ok": bool(np.mean(losses[half:]) < np.mean(losses[:half]))
            and _ts_clean(res)}


def _moe_cost_spread(prog: MoERoutingProgram) -> tuple[float, float]:
    """(min, max) expert task cost of one routing round — the measured
    irregularity of the non-regular program."""
    costs = [GLOBAL_OPS.cost(t) for t in prog.probe_expert_tasks()]
    return (min(costs), max(costs)) if costs else (0.0, 0.0)


def run_moe(smoke: bool, backend: str | None, faults: bool) -> dict:
    steps = (12 if smoke else 24) if faults else (8 if smoke else 16)
    prog = MoERoutingProgram(steps=steps, seed=0)
    plan = (FaultPlan(interval=0.1, speed_levels=(1.0, 5.0, 10.0),
                      p_speed_change=1.0, p_handler_crash=1.0,
                      p_manager_crash=1.0, seed=1)
            if faults else FaultPlan(interval=1e9))
    # The faults gate requires >= 1 manager AND handler revival, so the
    # workload must outlive several plan ticks on a machine of any speed:
    # scale the emulated per-task compute up for that leg instead of
    # trusting wallclock luck.
    time_scale = 2e-5 if faults else 1e-6
    cfg = CloudConfig(n_handlers=4, task_cap=256.0, pouch_size=64,
                      time_scale=time_scale, initial_timeout=0.1,
                      fault_plan=plan, wall_limit=240.0,
                      ts_backend=_checked(backend),
                      **_race_kwargs(backend))
    res = ACANCloud(cfg, program=prog).run()
    losses = [l for _, l in res.loss_history]
    lo, hi = _moe_cost_spread(prog)
    completed = len(losses) == steps
    decreased = bool(len(losses) >= 4
                     and np.mean(losses[-3:]) < np.mean(losses[:3]))
    out = {"name": "program_moe_faults" if faults else "program_moe",
           "wall": res.wallclock, "ts_ops": _ts_ops(res),
           "pouches": res.pouches, "first": float(np.mean(losses[:3])),
           "last": float(np.mean(losses[-3:])), "completed": completed,
           "cost_min": lo, "cost_max": hi,
           "mgr_revive": res.manager_revivals,
           "hdl_revive": res.handler_revivals,
           "races": len(res.race_report),
           "ts_clean": _ts_clean(res)}
    if faults:
        out["ok"] = (completed and decreased and res.manager_revivals >= 1
                     and res.handler_revivals >= 1 and _ts_clean(res))
    else:
        out["ok"] = completed and decreased and hi > lo and _ts_clean(res)
    return out


def run_multi(smoke: bool, backend: str | None) -> dict:
    """The multi-tenant co-residency gate: MLP + MoE on ONE space, one
    shared handler fleet, exp3-style faults — both must complete with
    revivals and zero deletes capable of crossing a namespace."""
    # 2 epochs like run_mlp: SGD bs=1 is noisy — a single epoch over few
    # samples does not give a stable first-half/second-half comparison.
    epochs, n_samples = (2, 8) if smoke else (2, 24)
    moe_steps = 10 if smoke else 20
    inner = _checked(backend)
    cfg = CloudConfig(layers=[LayerSpec(32, 32), LayerSpec(32, 1)],
                      n_handlers=4, epochs=epochs, n_samples=n_samples,
                      task_cap=256.0, pouch_size=64, lr=0.01,
                      time_scale=2e-5, initial_timeout=0.1,
                      fault_plan=FaultPlan(
                          interval=0.1, speed_levels=(1.0, 5.0, 10.0),
                          p_speed_change=1.0, p_handler_crash=1.0,
                          p_manager_crash=1.0, seed=1),
                      wall_limit=240.0, ts_backend=f"instrumented+{inner}",
                      **_race_kwargs(backend))
    programs = [MLPProgram(cfg.layers, epochs=epochs, n_samples=n_samples,
                           seed=0),
                MoERoutingProgram(steps=moe_steps, seed=0)]
    cloud = ACANCloud(cfg, programs=programs)
    res = cloud.run()
    mlp = res.per_program["mlp"]
    moe = res.per_program["moe_routing"]
    mlp_losses = [l for _, l in mlp.loss_history]
    moe_losses = [l for _, l in moe.loss_history]
    completed = (len(mlp_losses) == epochs * n_samples
                 and len(moe_losses) == moe_steps)
    # zero cross-namespace task deletions: no widened-subject deletes and
    # nothing removed under an unscoped "task" subject.
    dm = cloud.ts.backend.delete_metrics()
    cross_free = (cloud.ts.stats()["instr_widened_deletes"] == 0
                  and dm.get("task", {"removed": 0})["removed"] == 0)
    half = len(mlp_losses) // 2
    decreased = bool(
        mlp_losses and moe_losses and len(moe_losses) >= 6
        and np.mean(mlp_losses[half:]) < np.mean(mlp_losses[:half])
        and np.mean(moe_losses[-3:]) < np.mean(moe_losses[:3]))
    return {"name": "program_multi",
            "wall": res.wallclock,
            "ts_ops": res.ts_stats.get("puts", 0)
            + res.ts_stats.get("takes", 0) + res.ts_stats.get("reads", 0),
            "pouches": mlp.pouches + moe.pouches,
            "first": float(np.mean(mlp_losses[:half])) if half else 0.0,
            "last": float(np.mean(mlp_losses[half:])) if half else 0.0,
            "completed": completed,
            "mgr_revive": res.manager_revivals,
            "hdl_revive": res.handler_revivals,
            "cross_ns_free": cross_free,
            "races": len(res.race_report),
            "ts_clean": _ts_clean(res),
            "ok": (completed and decreased and cross_free
                   and res.manager_revivals >= 1
                   and res.handler_revivals >= 1 and _ts_clean(res))}


def run_jax(smoke: bool, backend: str | None) -> dict:
    from repro.configs import get_config
    from repro.ts_exec.step_runner import ACANStepRunner, ACANTrainConfig
    steps = 4 if smoke else 8
    runner = ACANStepRunner(
        get_config("smollm_360m", reduced=True),
        ACANTrainConfig(n_handlers=3, n_micro=3, micro_batch=2, seq=32,
                        steps=steps, lr=0.05, timeout=20.0,
                        handler_crash_prob=0.25, seed=0,
                        ts_backend=_checked(backend)))
    t0 = time.perf_counter()
    res = runner.run()
    wall = time.perf_counter() - t0
    return {"name": "program_jax_sgd", "wall": wall, "ts_ops": 0,
            "pouches": res.param_versions, "first": res.losses[0],
            "last": res.losses[-1], "completed": len(res.losses) == steps,
            "crashes": res.crashes, "reissues": res.reissues,
            "ts_clean": _ts_clean(res),
            "ok": bool(len(res.losses) == steps
                       and res.losses[-1] < res.losses[0])
            and _ts_clean(res)}


def run_programs(programs: list[str], smoke: bool,
                 backend: str | None) -> list[dict]:
    out = []
    for name in programs:
        if name == "mlp":
            out.append(run_mlp(smoke, backend))
        elif name == "moe":
            out.append(run_moe(smoke, backend, faults=False))
        elif name == "moe_faults":
            out.append(run_moe(smoke, backend, faults=True))
        elif name == "jax":
            out.append(run_jax(smoke, backend))
        elif name == "multi":
            out.append(run_multi(smoke, backend))
        else:
            raise SystemExit(f"unknown program {name!r}")
    return out


def bench_rows(smoke: bool = True, backend: str | None = None,
               include_jax: bool = False) -> list[tuple[str, float, str]]:
    """CSV rows for the benchmarks/run.py harness."""
    programs = (["mlp", "moe", "moe_faults"]
                + (["jax"] if include_jax else []) + ["multi"])
    rows = []
    for r in run_programs(programs, smoke, backend):
        derived = (f"loss {r['first']:.3f}->{r['last']:.3f} "
                   f"completed={r['completed']} pouches={r['pouches']} "
                   f"ok={r['ok']}")
        if "cost_max" in r:
            derived += (f" cost_spread={r['cost_min']:.0f}"
                        f"..{r['cost_max']:.0f}")
        if "mgr_revive" in r and r["name"].endswith(("faults", "multi")):
            derived += (f" mgr_revive={r['mgr_revive']} "
                        f"hdl_revive={r['hdl_revive']}")
        if "cross_ns_free" in r:
            derived += f" cross_ns_free={r['cross_ns_free']}"
        if "races" in r:
            derived += f" races={r['races']}"
        if "ts_clean" in r:
            derived += f" ts_clean={r['ts_clean']}"
        rows.append((r["name"], r["wall"] * 1e6, derived))
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", default=None,
                    help="tuple-space backend spec (default: "
                         "$REPRO_TS_BACKEND or local)")
    ap.add_argument("--programs", default=DEFAULT_PROGRAMS,
                    help=f"comma list (default: {DEFAULT_PROGRAMS})")
    ap.add_argument("--smoke", action="store_true",
                    help="short CI run: fewer rounds per program")
    args = ap.parse_args()

    results = run_programs([p.strip() for p in args.programs.split(",") if p],
                           args.smoke, args.backend)
    print(f"{'program':<22}{'wall(s)':>9}{'ts_ops':>10}{'pouches':>9}"
          f"{'loss first->last':>20}{'ok':>5}")
    print("-" * 75)
    for r in results:
        print(f"{r['name']:<22}{r['wall']:>9.2f}{r['ts_ops']:>10,}"
              f"{r['pouches']:>9}"
              f"{r['first']:>11.3f} ->{r['last']:>7.3f}{str(r['ok']):>5}")
        extras = {k: r[k] for k in
                  ("cost_min", "cost_max", "mgr_revive", "hdl_revive",
                   "crashes", "reissues", "cross_ns_free", "races",
                   "ts_clean")
                  if k in r}
        if extras:
            print(f"{'':<22}{extras}")
    ok = all(r["ok"] for r in results)
    print(f"\nacceptance: {'PASS' if ok else 'FAIL'} "
          f"({sum(r['ok'] for r in results)}/{len(results)} programs)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
