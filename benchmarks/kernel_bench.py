"""Kernel micro-benchmarks. On CPU the Pallas kernels run in interpret
mode (orders of magnitude slower than compiled TPU code), so the numbers
reported are for the pure-jnp reference paths (the math the TPU kernels
implement), timed compiled; the interpret-mode kernels are timed separately
as a correctness-path sanity number, not a performance claim."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6     # µs


def bench_tile_matmul() -> list[tuple[str, float, str]]:
    from repro.kernels.tile_matmul.ref import tile_matmul_ref
    from repro.kernels.tile_matmul.ops import matmul
    key = jax.random.PRNGKey(0)
    rows = []
    for (m, k, n) in [(256, 256, 256), (512, 1024, 512)]:
        x = jax.random.normal(key, (m, k), jnp.float32)
        w = jax.random.normal(key, (k, n), jnp.float32)
        us_ref = _time(jax.jit(lambda a, b: tile_matmul_ref(a, b,
                                                            activation="tanh")),
                       x, w)
        flops = 2 * m * k * n
        rows.append((f"tile_matmul_ref_{m}x{k}x{n}", us_ref,
                     f"{flops / (us_ref * 1e-6) / 1e9:.1f}GFLOP/s"))
        if m <= 256:
            us_k = _time(lambda a, b: matmul(a, b, activation="tanh",
                                             bm=128, bn=128, bk=128), x, w)
            rows.append((f"tile_matmul_interpret_{m}x{k}x{n}", us_k,
                         "correctness-path"))
    return rows


def bench_attention() -> list[tuple[str, float, str]]:
    from repro.models.attention import chunked_attention
    key = jax.random.PRNGKey(0)
    rows = []
    for (b, t, h, d) in [(1, 1024, 8, 64), (2, 2048, 8, 64)]:
        q = jax.random.normal(key, (b, t, h, d), jnp.bfloat16)
        k = jax.random.normal(key, (b, t, h, d), jnp.bfloat16)
        v = jax.random.normal(key, (b, t, h, d), jnp.bfloat16)
        fn = jax.jit(lambda q, k, v: chunked_attention(q, k, v, q_chunk=256,
                                                       kv_chunk=256))
        us = _time(fn, q, k, v)
        flops = 4 * b * t * t * h * d / 2            # causal half
        rows.append((f"flash_ref_b{b}_t{t}", us,
                     f"{flops / (us * 1e-6) / 1e9:.1f}GFLOP/s"))
    return rows


def bench_ssd() -> list[tuple[str, float, str]]:
    from repro.models.mamba2 import ssd_chunked
    key = jax.random.PRNGKey(0)
    rows = []
    for (b, t, h, p, n) in [(2, 1024, 8, 64, 64)]:
        x = jax.random.normal(key, (b, t, h, p), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(key, (b, t, h)))
        A = -jnp.exp(jax.random.normal(key, (h,)) * 0.3)
        B = jax.random.normal(key, (b, t, 1, n))
        C = jax.random.normal(key, (b, t, 1, n))
        D = jnp.ones((h,))
        fn = jax.jit(lambda *a: ssd_chunked(*a, 128)[0])
        us = _time(fn, x, dt, A, B, C, D)
        rows.append((f"ssd_chunked_b{b}_t{t}", us,
                     f"{b * t / (us * 1e-6) / 1e6:.2f}Mtok/s"))
    return rows


def bench_tuplespace() -> list[tuple[str, float, str]]:
    # Single-thread facade rates per space backend; the full multi-threaded
    # comparison (contention, blocking, pattern matching) lives in
    # benchmarks/ts_bench.py.
    from repro.core import TupleSpace
    rows = []
    N = 20000
    for spec in ("local", "sharded"):
        ts = TupleSpace(backend=spec)
        t0 = time.perf_counter()
        for i in range(N):
            ts.put(("k", i), i)
        put_us = (time.perf_counter() - t0) / N * 1e6
        t0 = time.perf_counter()
        for i in range(N):
            ts.get(("k", i))
        get_us = (time.perf_counter() - t0) / N * 1e6
        rows.append((f"tuplespace_put_{spec}", put_us,
                     f"{1e6 / put_us:.0f}ops/s"))
        rows.append((f"tuplespace_get_exact_{spec}", get_us,
                     f"{1e6 / get_us:.0f}ops/s"))
    return rows
