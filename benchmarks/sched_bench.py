"""Control-plane scheduling benchmark — event-driven vs polling (PR 2).

    PYTHONPATH=src python benchmarks/sched_bench.py [--smoke] [--backend B]

Runs the paper's §6.1 workload (2-layer MLP, 4 handlers) twice on the
same tuple-space backend wrapped in ``InstrumentedBackend`` — once with
``scheduling="poll"`` (the pre-PR-2 fixed-cadence control plane: 4 ms
done-mark scans in the Manager, 50 ms single-``get`` loops in Handlers,
20 ms finished-flag busy-wait in the Cloud) and once with
``scheduling="event"`` (blocking ``wait_count`` pouch barriers, batched
``take_batch`` task pickup, blocking finished ``read``) — and reports per
mode:

- **TS ops / pouch** — total instrumented tuple-space operations divided
  by completed pouch rounds (the control-plane cost of one unit of
  scheduling progress);
- **idle wakeups** — ``try_read``/``try_get`` misses plus blocking-op
  timeouts: wakeups that accomplished nothing;
- wallclock and the mean loss of the final epoch (trajectories must
  agree across modes — scheduling must not perturb training numerics).

It also reports the **pipeline** row (PR 5): the MoE routing workload —
whose per-expert stage DAG leaves handlers idle whenever one stage's
pouch does not fill the fleet — run once sequentially
(``max_inflight_stages=1``) and once under the frontier scheduler
(``max_inflight_stages=8``), comparing **makespan** and **handler
utilisation** (emulated busy seconds / fleet wallclock) with identical
loss trajectories.

And the **autotune** row (PR 7): the same MoE workload on a
heterogeneous fleet (speeds ``AUTOTUNE_SPEEDS``), static frontier-8
knobs vs the online cost model (``CloudConfig.autotune=True`` — learned
per-(op, handler) latencies drive drain order, slow-handler deferral,
frontier width and pouch sizing), with ``--autotune-only`` running just
that gate (the CI checked-backend leg).

Acceptance (exit code): event mode must use **>= 5x fewer TS ops per
completed pouch** than poll mode, with wallclock no worse (1.15x slack
for timer noise) and matching loss trajectories (1e-3 rtol — the batched
executor may reassociate float reductions); the pipelined MoE run must
beat the sequential makespan by **>= PIPELINE_SPEEDUP_FLOOR** with
higher handler utilisation and a bit-identical trajectory; the autotune
run must beat the static heterogeneous-fleet makespan by
**>= AUTOTUNE_SPEEDUP_FLOOR** with an identical trajectory and (under a
checked backend) zero protocol violations and zero leaks.
"""

from __future__ import annotations

import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core import (ACANCloud, CloudConfig, FaultPlan, LayerSpec,  # noqa: E402
                        MoERoutingProgram)
from repro.configs.paper_mlp import PAPER_LR  # noqa: E402

#: ops-per-pouch improvement the event-driven control plane must deliver.
OPS_RATIO_FLOOR = 5.0
WALLCLOCK_SLACK = 1.15
#: makespan improvement the frontier scheduler must deliver on the MoE
#: stage DAG (measured ~1.8x on 4 handlers; floor leaves CI timer slack).
PIPELINE_SPEEDUP_FLOOR = 1.25
#: makespan improvement the online cost model must deliver on the MoE DAG
#: over the static frontier-8 baseline when the fleet is heterogeneous
#: (speed ratios drawn from the paper's §6 1:5:10 palette): LPT drain
#: ordering plus slow-handler deferral keep the expert groups off the
#: slow boxes (measured 1.25–1.6x over 8 runs on both backends).
AUTOTUNE_SPEEDUP_FLOOR = 1.2
#: Heterogeneous speed ratios used by the autotune gate. Three slow
#: boxes + one 10x box maximises how much FIFO draining hurts the static
#: baseline, which is exactly the placement problem the model solves.
AUTOTUNE_SPEEDS = [1.0, 1.0, 1.0, 10.0]
#: makespan ceiling for stacking the happens-before race sanitizer
#: (``raced+``) onto the checked width-8 MoE pipeline run: the sanitizer
#: is metadata-only bookkeeping (vector clocks + access journals, no
#: payload copies), so it must stay within 15% of the checked makespan.
RACED_OVERHEAD_CEIL = 1.15
#: makespan improvement the out-of-process fleet (PR 10) must deliver
#: over the thread fleet on the autotuned width-8 MoE pipeline when
#: handler compute actually holds the GIL (``compute_mode="spin"``):
#: real processes overlap where threads serialise. Only meaningful with
#: >= PROCESS_FLEET_MIN_CORES cores — below that the gate skips (threads
#: and processes share one core and nothing can overlap).
PROCESS_FLEET_SPEEDUP_FLOOR = 1.5
PROCESS_FLEET_MIN_CORES = 4


def run_mode(scheduling: str, backend: str, layers, epochs: int,
             n_samples: int, seed: int, adaptive_pouch: bool = False) -> dict:
    cfg = CloudConfig(
        layers=layers, n_handlers=4, epochs=epochs, n_samples=n_samples,
        task_cap=256.0, pouch_size=100, lr=PAPER_LR, time_scale=2e-6,
        initial_timeout=0.25, fault_plan=FaultPlan(interval=1e9),
        seed=seed, wall_limit=600.0, scheduling=scheduling,
        ts_backend=f"instrumented:{backend}", adaptive_pouch=adaptive_pouch)
    cloud = ACANCloud(cfg)
    res = cloud.run()
    metrics = cloud.ts.backend.metrics()
    stats = cloud.ts.stats()
    ops = stats["instr_ops"]
    pouches = max(res.pouches, 1)
    return {
        "scheduling": scheduling,
        "ops": ops,
        "pouches": res.pouches,
        "ops_per_pouch": ops / pouches,
        "idle_wakeups": stats["instr_misses"] + stats["instr_timeouts"],
        "wallclock": res.wallclock,
        "losses": [l for _, l in res.loss_history],
        "per_op": {op: int(m["calls"]) for op, m in sorted(metrics.items())},
    }


def run_pipeline_mode(max_inflight: int, backend: str, steps: int,
                      seed: int) -> dict:
    """One MoE run at the given frontier width. ``handler_batch=1`` keeps
    handlers from draining a whole narrow stage into one thread, so the
    comparison isolates *stage-level* concurrency (what the frontier
    adds) from batch-drain serialisation (orthogonal, PR 2)."""
    prog = MoERoutingProgram(steps=steps, seed=seed)
    cfg = CloudConfig(n_handlers=4, task_cap=128.0, pouch_size=64,
                      time_scale=2e-4, initial_timeout=0.25,
                      handler_batch=1, fault_plan=FaultPlan(interval=1e9),
                      wall_limit=600.0, ts_backend=backend,
                      max_inflight_stages=max_inflight)
    cloud = ACANCloud(cfg, program=prog)
    res = cloud.run()
    return {
        "max_inflight": max_inflight,
        "wallclock": res.wallclock,
        "utilisation": (cloud.handler_busy_time()
                        / max(cfg.n_handlers * res.wallclock, 1e-9)),
        "losses": [l for _, l in res.loss_history],
        "completed": len(res.loss_history) == steps,
        "pouches": res.pouches,
        "races": len(res.race_report),
    }


def run_autotune_mode(autotune: bool, backend: str, steps: int,
                      seed: int) -> dict:
    """One MoE run on the heterogeneous fleet, static frontier-8 knobs vs
    the online cost model. ``handler_batch=4`` gives the drain-order and
    deferral levers room to act (a 1-task batch has nothing to reorder);
    both runs share it, so the comparison isolates the model."""
    prog = MoERoutingProgram(steps=steps, seed=seed)
    cfg = CloudConfig(n_handlers=4, task_cap=128.0, pouch_size=64,
                      time_scale=2e-4, initial_timeout=0.25,
                      handler_batch=4, fault_plan=FaultPlan(interval=1e9),
                      wall_limit=600.0, ts_backend=backend,
                      max_inflight_stages=8,
                      handler_speeds=list(AUTOTUNE_SPEEDS),
                      autotune=autotune)
    cloud = ACANCloud(cfg, program=prog)
    res = cloud.run()
    return {
        "autotune": autotune,
        "wallclock": res.wallclock,
        "utilisation": (cloud.handler_busy_time()
                        / max(cfg.n_handlers * res.wallclock, 1e-9)),
        "losses": [l for _, l in res.loss_history],
        "completed": len(res.loss_history) == steps,
        "pouches": res.pouches,
        "deferred": res.cost_report.get("tasks_deferred", 0),
        "ts_violations": res.ts_violations,
        "ts_leaks": res.ts_leaks,
    }


def run_fleet_mode(fleet: str, backend: str, steps: int, seed: int) -> dict:
    """One autotuned width-8 MoE run with GIL-holding emulated compute
    (``compute_mode="spin"``) on the given fleet. The thread fleet
    serialises every spin slice on the GIL; the process fleet overlaps
    them for real — the contrast the PR 10 gate measures."""
    prog = MoERoutingProgram(steps=steps, seed=seed)
    cfg = CloudConfig(n_handlers=4, task_cap=128.0, pouch_size=64,
                      time_scale=2e-4, initial_timeout=0.25,
                      handler_batch=4, fault_plan=FaultPlan(interval=1e9),
                      wall_limit=600.0, ts_backend=backend,
                      max_inflight_stages=8, autotune=True,
                      fleet=fleet, compute_mode="spin")
    cloud = ACANCloud(cfg, program=prog)
    res = cloud.run()
    return {
        "fleet": fleet,
        "wallclock": res.wallclock,
        "losses": [l for _, l in res.loss_history],
        "completed": len(res.loss_history) == steps,
        "ts_violations": res.ts_violations,
        "ts_leaks": res.ts_leaks,
    }


def process_fleet_gate(smoke: bool, backend: str, seed: int = 0) -> dict:
    """Thread fleet vs out-of-process fleet (PR 10) on the autotuned MoE
    pipeline with spin compute: the GIL-escape acceptance gate. Loss
    trajectories must be bit-identical (the fleet is an execution detail,
    not a numerics one). Skips — passing, with a note — on boxes where
    no speedup is physically possible (< PROCESS_FLEET_MIN_CORES cores)."""
    cores = os.cpu_count() or 1
    if cores < PROCESS_FLEET_MIN_CORES:
        return {"skipped": (f"only {cores} core(s) — the GIL-escape "
                            f"contrast needs >= {PROCESS_FLEET_MIN_CORES}"),
                "ok": True}
    steps = 5 if smoke else 10
    thread = run_fleet_mode("thread", backend, steps, seed)
    proc = run_fleet_mode("process", backend, steps, seed)
    speedup = thread["wallclock"] / max(proc["wallclock"], 1e-9)
    loss_ok = (thread["completed"] and proc["completed"]
               and thread["losses"] == proc["losses"])   # bit-identical
    clean = proc["ts_violations"] == 0 and not proc["ts_leaks"]
    ok = speedup >= PROCESS_FLEET_SPEEDUP_FLOOR and loss_ok and clean
    return {"thread": thread, "process": proc, "speedup": speedup,
            "loss_ok": loss_ok, "clean": clean, "ok": ok}


def autotune_gate(smoke: bool, backend: str, seed: int = 0) -> dict:
    """Static frontier-8 vs cost-model autotune on the 1:1:1:10 fleet:
    the learned-latency acceptance gate. The trajectory must stay
    identical (the model only reorders/right-sizes scheduling; MoE is
    width-invariant), and under a checked backend the new cstats traffic
    must be violation- and leak-free."""
    # More steps than the pipeline gate: the model needs a few batches to
    # fit before deferral bites, and the amortised contrast is what the
    # floor protects — 5 steps is cold-start-dominated and noisy.
    steps = 10 if smoke else 15
    static = run_autotune_mode(False, backend, steps, seed)
    auto = run_autotune_mode(True, backend, steps, seed)
    speedup = static["wallclock"] / max(auto["wallclock"], 1e-9)
    loss_ok = (static["completed"] and auto["completed"]
               and static["losses"] == auto["losses"])   # identical
    clean = auto["ts_violations"] == 0 and not auto["ts_leaks"]
    ok = speedup >= AUTOTUNE_SPEEDUP_FLOOR and loss_ok and clean
    return {"static": static, "auto": auto, "speedup": speedup,
            "loss_ok": loss_ok, "clean": clean, "ok": ok}


def raced_overhead_gate(smoke: bool, backend: str, seed: int = 0) -> dict:
    """Checked vs raced+checked on the width-8 MoE pipeline run (PR 8):
    the happens-before sanitizer must stay within RACED_OVERHEAD_CEIL of
    the checked makespan, report zero races on the built-in DAG, and
    leave the loss trajectory bit-identical."""
    steps = 5 if smoke else 10
    checked = backend if "checked" in backend else f"checked+{backend}"
    raced_spec = checked if "raced" in checked else f"raced+{checked}"
    base = run_pipeline_mode(8, checked, steps, seed)
    raced = run_pipeline_mode(8, raced_spec, steps, seed)
    overhead = raced["wallclock"] / max(base["wallclock"], 1e-9)
    loss_ok = (base["completed"] and raced["completed"]
               and base["losses"] == raced["losses"])   # bit-identical
    ok = (overhead <= RACED_OVERHEAD_CEIL and loss_ok
          and raced["races"] == 0)
    return {"checked": base, "raced": raced, "overhead": overhead,
            "loss_ok": loss_ok, "ok": ok}


def pipeline_gate(smoke: bool, backend: str, seed: int = 0) -> dict:
    """Sequential vs pipelined MoE: the overlap-speedup acceptance gate."""
    steps = 5 if smoke else 10
    seq = run_pipeline_mode(1, backend, steps, seed)
    pipe = run_pipeline_mode(8, backend, steps, seed)
    speedup = seq["wallclock"] / max(pipe["wallclock"], 1e-9)
    loss_ok = (seq["completed"] and pipe["completed"]
               and seq["losses"] == pipe["losses"])   # bit-identical
    ok = (speedup >= PIPELINE_SPEEDUP_FLOOR
          and pipe["utilisation"] > seq["utilisation"]
          and loss_ok)
    return {"seq": seq, "pipe": pipe, "speedup": speedup,
            "loss_ok": loss_ok, "ok": ok}


def bench_rows(smoke: bool = True,
               backend: str = "sharded") -> list[tuple[str, float, str]]:
    """CSV rows for the benchmarks/run.py harness: one row per scheduling
    mode plus the poll/event ops-per-pouch ratio row the 5x gate watches."""
    epochs, samples = (1, 8) if smoke else (2, 100)
    layers = [LayerSpec(256, 256), LayerSpec(256, 1)]
    results = {s: run_mode(s, backend, layers, epochs, samples, 0)
               for s in ("poll", "event")}
    rows = [(f"sched_{s}_{backend}", r["wallclock"] * 1e6,
             f"ts_ops={r['ops']} ops_per_pouch={r['ops_per_pouch']:.1f} "
             f"idle_wakeups={r['idle_wakeups']} pouches={r['pouches']}")
            for s, r in results.items()]
    ratio = (results["poll"]["ops_per_pouch"]
             / max(results["event"]["ops_per_pouch"], 1e-9))
    rows.append((f"sched_poll_over_event_{backend}", 0.0,
                 f"ops_per_pouch_ratio={ratio:.1f}x "
                 f"gate>={OPS_RATIO_FLOOR:.0f}x "
                 f"pass={ratio >= OPS_RATIO_FLOOR}"))
    # Adaptive pouch sizing (PouchController in the Manager) vs the fixed
    # §6 pouch_size=100 baseline, both in event mode: measured, not gated
    # — adaptation pays off on wide stages/heterogeneous fleets, and the
    # row keeps the wiring honest (it must complete the same trajectory).
    fixed = results["event"]
    adap = run_mode("event", backend, layers, epochs, samples, 0,
                    adaptive_pouch=True)
    loss_ok = (len(adap["losses"]) == len(fixed["losses"])
               and np.allclose(adap["losses"], fixed["losses"],
                               rtol=1e-3, atol=1e-5))
    rows.append((f"sched_adaptive_pouch_{backend}", adap["wallclock"] * 1e6,
                 f"ts_ops={adap['ops']} "
                 f"ops_per_pouch={adap['ops_per_pouch']:.1f} "
                 f"pouches={adap['pouches']} "
                 f"(fixed: {fixed['pouches']}) loss_match={loss_ok}"))
    # Frontier scheduler vs sequential stage execution on the MoE DAG
    # (PR 5) — makespan + handler utilisation, trajectories bit-identical.
    pg = pipeline_gate(smoke, backend)
    rows.append((f"sched_pipeline_{backend}", pg["pipe"]["wallclock"] * 1e6,
                 f"seq={pg['seq']['wallclock']:.2f}s "
                 f"pipe={pg['pipe']['wallclock']:.2f}s "
                 f"speedup={pg['speedup']:.2f}x "
                 f"util={pg['seq']['utilisation']:.2f}->"
                 f"{pg['pipe']['utilisation']:.2f} "
                 f"loss_match={pg['loss_ok']} "
                 f"gate>={PIPELINE_SPEEDUP_FLOOR:.2f}x pass={pg['ok']}"))
    # Online cost model vs static knobs on the heterogeneous fleet (PR 7)
    # — learned latencies drive drain order, deferral, width and pouch.
    ag = autotune_gate(smoke, backend)
    rows.append((f"sched_autotune_{backend}",
                 ag["auto"]["wallclock"] * 1e6,
                 f"static={ag['static']['wallclock']:.2f}s "
                 f"auto={ag['auto']['wallclock']:.2f}s "
                 f"speedup={ag['speedup']:.2f}x "
                 f"deferred={ag['auto']['deferred']} "
                 f"loss_match={ag['loss_ok']} clean={ag['clean']} "
                 f"gate>={AUTOTUNE_SPEEDUP_FLOOR:.2f}x pass={ag['ok']}"))
    # Happens-before race sanitizer overhead (PR 8) — raced+checked vs
    # checked on the width-8 MoE pipeline: vector-clock bookkeeping only,
    # zero races on the built-in DAG, bit-identical trajectory.
    rg = raced_overhead_gate(smoke, backend)
    rows.append((f"sched_raced_overhead_{backend}",
                 rg["raced"]["wallclock"] * 1e6,
                 f"checked={rg['checked']['wallclock']:.2f}s "
                 f"raced={rg['raced']['wallclock']:.2f}s "
                 f"overhead={rg['overhead']:.2f}x "
                 f"races={rg['raced']['races']} "
                 f"loss_match={rg['loss_ok']} "
                 f"gate<={RACED_OVERHEAD_CEIL:.2f}x pass={rg['ok']}"))
    # Out-of-process fleet vs thread fleet (PR 10) — GIL-holding spin
    # compute, autotuned width-8 MoE, bit-identical trajectories.
    fg = process_fleet_gate(smoke, backend)
    if "skipped" in fg:
        rows.append((f"sched_process_fleet_{backend}", 0.0,
                     f"SKIPPED: {fg['skipped']}"))
    else:
        rows.append((f"sched_process_fleet_{backend}",
                     fg["process"]["wallclock"] * 1e6,
                     f"thread={fg['thread']['wallclock']:.2f}s "
                     f"process={fg['process']['wallclock']:.2f}s "
                     f"speedup={fg['speedup']:.2f}x "
                     f"loss_match={fg['loss_ok']} clean={fg['clean']} "
                     f"gate>={PROCESS_FLEET_SPEEDUP_FLOOR:.2f}x "
                     f"pass={fg['ok']}"))
    return rows


def _print_process_fleet(fg: dict) -> None:
    if "skipped" in fg:
        print(f"process fleet (MoE, spin compute): SKIPPED — "
              f"{fg['skipped']}")
        return
    print(f"process fleet (MoE, spin compute, autotune width 8): "
          f"thread={fg['thread']['wallclock']:.2f}s "
          f"process={fg['process']['wallclock']:.2f}s "
          f"speedup={fg['speedup']:.2f}x "
          f"(target >= {PROCESS_FLEET_SPEEDUP_FLOOR:.2f}x), "
          f"trajectory {'bit-identical' if fg['loss_ok'] else 'DIVERGES'}, "
          f"ts_violations={fg['process']['ts_violations']}, "
          f"ts_leaks={len(fg['process']['ts_leaks'])} "
          f"-> {'PASS' if fg['ok'] else 'FAIL'}")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", default="sharded",
                    help="inner tuple-space backend spec (default: sharded)")
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--samples", type=int, default=100)
    ap.add_argument("--dim", type=int, default=256,
                    help="hidden width (paper §6.1: 256)")
    ap.add_argument("--smoke", action="store_true",
                    help="short CI run: same 256-wide §6.1 geometry "
                         "(pouches must span several poll ticks for the "
                         "comparison to be representative), 1 epoch, "
                         "8 samples")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--autotune-only", action="store_true",
                    help="run only the cost-model autotune gate (the CI "
                         "checked-backend leg: speedup + identical "
                         "trajectory + zero ts violations/leaks)")
    ap.add_argument("--process-fleet-only", action="store_true",
                    help="run only the PR 10 out-of-process fleet gate "
                         "(thread vs process, spin compute, bit-identical "
                         "trajectory; skips below "
                         f"{PROCESS_FLEET_MIN_CORES} cores)")
    args = ap.parse_args()

    if args.process_fleet_only:
        fg = process_fleet_gate(args.smoke, args.backend, args.seed)
        _print_process_fleet(fg)
        return 0 if fg["ok"] else 1

    if args.autotune_only:
        ag = autotune_gate(args.smoke, args.backend, args.seed)
        print(f"autotune (MoE, speeds {AUTOTUNE_SPEEDS}): "
              f"static={ag['static']['wallclock']:.2f}s "
              f"auto={ag['auto']['wallclock']:.2f}s "
              f"speedup={ag['speedup']:.2f}x "
              f"(target >= {AUTOTUNE_SPEEDUP_FLOOR:.2f}x), "
              f"deferred={ag['auto']['deferred']}, "
              f"trajectory {'identical' if ag['loss_ok'] else 'DIVERGES'}, "
              f"ts_violations={ag['auto']['ts_violations']}, "
              f"ts_leaks={len(ag['auto']['ts_leaks'])} "
              f"-> {'PASS' if ag['ok'] else 'FAIL'}")
        return 0 if ag["ok"] else 1

    if args.smoke:
        args.epochs, args.samples = 1, 8
    layers = [LayerSpec(args.dim, args.dim), LayerSpec(args.dim, 1)]

    results = {}
    for scheduling in ("poll", "event"):
        results[scheduling] = run_mode(scheduling, args.backend, layers,
                                       args.epochs, args.samples, args.seed)
    adap = run_mode("event", args.backend, layers, args.epochs,
                    args.samples, args.seed, adaptive_pouch=True)

    poll, event = results["poll"], results["event"]
    width = 18
    print(f"{'':<{width}}{'poll':>14}{'event':>14}{'poll/event':>12}")
    print("-" * (width + 40))
    for label, key in [("TS ops total", "ops"),
                       ("pouches", "pouches"),
                       ("TS ops / pouch", "ops_per_pouch"),
                       ("idle wakeups", "idle_wakeups"),
                       ("wallclock (s)", "wallclock")]:
        p, e = poll[key], event[key]
        ratio = p / e if e else float("inf")
        print(f"{label:<{width}}{p:>14,.1f}{e:>14,.1f}{ratio:>11.1f}x")
    print(f"\nper-op calls, poll : {poll['per_op']}")
    print(f"per-op calls, event: {event['per_op']}")
    adap_loss_ok = (len(adap["losses"]) == len(event["losses"])
                    and np.allclose(adap["losses"], event["losses"],
                                    rtol=1e-3, atol=1e-5))
    print(f"adaptive pouch (event): pouches={adap['pouches']} "
          f"(fixed: {event['pouches']}), "
          f"ops/pouch={adap['ops_per_pouch']:.1f} "
          f"(fixed: {event['ops_per_pouch']:.1f}), "
          f"wallclock={adap['wallclock']:.2f}s, "
          f"loss_match={adap_loss_ok}")

    pg = pipeline_gate(args.smoke, args.backend, args.seed)
    print(f"\npipeline (MoE stage DAG, frontier vs sequential): "
          f"seq={pg['seq']['wallclock']:.2f}s "
          f"pipe={pg['pipe']['wallclock']:.2f}s "
          f"speedup={pg['speedup']:.2f}x "
          f"(target >= {PIPELINE_SPEEDUP_FLOOR:.2f}x), "
          f"utilisation {pg['seq']['utilisation']:.2f} -> "
          f"{pg['pipe']['utilisation']:.2f}, "
          f"trajectory {'bit-identical' if pg['loss_ok'] else 'DIVERGES'}")

    ag = autotune_gate(args.smoke, args.backend, args.seed)
    print(f"autotune (MoE, heterogeneous speeds {AUTOTUNE_SPEEDS}): "
          f"static={ag['static']['wallclock']:.2f}s "
          f"auto={ag['auto']['wallclock']:.2f}s "
          f"speedup={ag['speedup']:.2f}x "
          f"(target >= {AUTOTUNE_SPEEDUP_FLOOR:.2f}x), "
          f"deferred={ag['auto']['deferred']}, "
          f"trajectory {'identical' if ag['loss_ok'] else 'DIVERGES'}")

    rg = raced_overhead_gate(args.smoke, args.backend, args.seed)
    print(f"raced sanitizer (MoE pipeline, width 8): "
          f"checked={rg['checked']['wallclock']:.2f}s "
          f"raced={rg['raced']['wallclock']:.2f}s "
          f"overhead={rg['overhead']:.2f}x "
          f"(ceiling <= {RACED_OVERHEAD_CEIL:.2f}x), "
          f"races={rg['raced']['races']}, "
          f"trajectory {'bit-identical' if rg['loss_ok'] else 'DIVERGES'}")

    fg = process_fleet_gate(args.smoke, args.backend, args.seed)
    _print_process_fleet(fg)

    ops_ratio = poll["ops_per_pouch"] / max(event["ops_per_pouch"], 1e-9)
    wall_ok = event["wallclock"] <= poll["wallclock"] * WALLCLOCK_SLACK
    loss_ok = (len(poll["losses"]) == len(event["losses"])
               and np.allclose(poll["losses"], event["losses"],
                               rtol=1e-3, atol=1e-5))
    ok = (ops_ratio >= OPS_RATIO_FLOOR and wall_ok and loss_ok
          and adap_loss_ok and pg["ok"] and ag["ok"] and rg["ok"]
          and fg["ok"])
    print(f"\nacceptance: ops/pouch poll/event = {ops_ratio:.1f}x "
          f"(target >= {OPS_RATIO_FLOOR:.0f}x), "
          f"wallclock {'OK' if wall_ok else 'WORSE'}, "
          f"loss trajectories {'match' if loss_ok else 'DIVERGE'}, "
          f"adaptive pouch {'matches' if adap_loss_ok else 'DIVERGES'}, "
          f"pipeline overlap {'PASS' if pg['ok'] else 'FAIL'}, "
          f"autotune {'PASS' if ag['ok'] else 'FAIL'}, "
          f"raced overhead {'PASS' if rg['ok'] else 'FAIL'} "
          f"-> {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
