"""Tuple-space backend benchmark — put/get/pattern-match throughput per
:mod:`repro.core.space` backend.

    PYTHONPATH=src python benchmarks/ts_bench.py [--threads N] [--ops N]

Phases (each reports ops/s per backend and the sharded/local speedup):

- ``contended put+get``: N threads, each a producer+consumer on its own
  subject — the Manager/Handler hot path under load. This is the
  acceptance phase: ShardedBackend must be >= 2x LocalBackend.
- ``blocking pipeline``: N/2 producer threads feeding N/2 blocking
  consumers (``get`` with timeout) — measures condvar wakeup efficiency
  (the local backend's single condition wakes every waiter on every put).
- ``done-mark polling``: fully-concrete ``try_read`` against a store with
  many live completion marks — the Manager ``_pending`` scan; the
  (subject, arity) index + concrete-pattern fast path make this O(1) on
  the sharded backend.
- ``take_batch``: drain a full queue 16-at-a-time — the Handler's
  batched pickup (one lock acquisition per batch instead of per tuple).
- ``single-thread put/get``: uncontended baseline.

Remote rows (PR 10, ``--remote`` / ``bench_rows()`` for the run.py
harness): the same hot paths over the wire — pipelined contended
put/get on one shared connection, pouch batching (one ``put_many`` +
one ``take_batch`` frame per round: 2 round-trips per pouch pair
regardless of batch size), and the invalidation-coherent read-through
cache vs uncached reads. Persisted with every harness invocation to
``runs/bench/BENCH_<n>.json``.
"""

from __future__ import annotations

import argparse
import os
import sys
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core.space import ANY, TSTimeout, make_backend  # noqa: E402

BACKENDS = ["local", "sharded", "sharded:16"]


def _run_threads(workers) -> float:
    threads = [threading.Thread(target=w) for w in workers]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return time.perf_counter() - t0


def bench_contended_putget(spec: str, n_threads: int, ops: int) -> float:
    """Each thread puts then takes on its own subject; ops/s over all ops."""
    ts = make_backend(spec)
    barrier = threading.Barrier(n_threads)

    def worker(tid: int):
        subject = f"s{tid}"
        barrier.wait()
        for i in range(ops):
            ts.put((subject, i), i)
            ts.try_get((subject, i))

    elapsed = _run_threads([lambda tid=t: worker(tid)
                            for t in range(n_threads)])
    return 2 * ops * n_threads / elapsed


def bench_blocking_pipeline(spec: str, n_threads: int, ops: int) -> float:
    """Producer threads feed blocking consumers; ops/s of *delivered*
    tuples (a consumer that starves into its timeout only counts what it
    actually took, and the shortfall is reported)."""
    ts = make_backend(spec)
    n_pairs = max(n_threads // 2, 1)
    barrier = threading.Barrier(2 * n_pairs)
    delivered = [0] * n_pairs

    def producer(tid: int):
        barrier.wait()
        for i in range(ops):
            ts.put((f"q{tid}", i), i)

    def consumer(tid: int):
        barrier.wait()
        while delivered[tid] < ops:
            try:
                ts.get((f"q{tid}",
                        lambda _i: True), timeout=5.0)
                delivered[tid] += 1
            except TSTimeout:
                return

    workers = [lambda tid=t: producer(tid) for t in range(n_pairs)]
    workers += [lambda tid=t: consumer(tid) for t in range(n_pairs)]
    elapsed = _run_threads(workers)
    total = sum(delivered)
    if total < ops * n_pairs:
        print(f"WARNING: {spec} blocking pipeline starved: "
              f"{total}/{ops * n_pairs} delivered", file=sys.stderr)
    return total / elapsed


def bench_done_polling(spec: str, live: int, polls: int) -> float:
    """Concrete-pattern try_read with `live` completion marks resident."""
    ts = make_backend(spec)
    ts.put_many(iter([(("done", "fwd", i, 0, 0, 64, 0, 64), f"h{i % 4}")
                      for i in range(live)]))
    t0 = time.perf_counter()
    for i in range(polls):
        ts.try_read(("done", "fwd", i % live, 0, 0, 64, 0, 64))
    return polls / (time.perf_counter() - t0)


def bench_take_batch(spec: str, ops: int, batch: int = 16) -> float:
    """Drain a full queue via take_batch vs one-at-a-time get — the
    Handler's batched pickup path (delivered tuples/s)."""
    ts = make_backend(spec)
    ts.put_many(iter([(("q", i), i) for i in range(ops)]))
    taken = 0
    t0 = time.perf_counter()
    while taken < ops:
        taken += len(ts.take_batch(("q", ANY), batch, timeout=1.0))
    return ops / (time.perf_counter() - t0)


def bench_single_thread(spec: str, ops: int) -> tuple[float, float]:
    ts = make_backend(spec)
    t0 = time.perf_counter()
    for i in range(ops):
        ts.put(("k", i), i)
    put_rate = ops / (time.perf_counter() - t0)
    t0 = time.perf_counter()
    for i in range(ops):
        ts.get(("k", i))
    get_rate = ops / (time.perf_counter() - t0)
    return put_rate, get_rate


# --------------------------------------------------------- remote (PR 10)
def bench_remote_contended(n_threads: int, ops: int) -> float:
    """Contended put/try_get over ONE shared pipelined connection to a
    private server — request ids correlate interleaved responses, so the
    threads share the socket without head-of-line blocking."""
    from repro.core.space.remote import RemoteBackend
    rb = RemoteBackend(server_spec="sharded")
    barrier = threading.Barrier(n_threads)

    def worker(tid: int):
        subject = f"s{tid}"
        barrier.wait()
        for i in range(ops):
            rb.put((subject, i), i)
            rb.try_get((subject, i))

    try:
        elapsed = _run_threads([lambda tid=t: worker(tid)
                                for t in range(n_threads)])
        return 2 * ops * n_threads / elapsed
    finally:
        rb.close()


def bench_remote_pouch_batching(ops: int, batch: int = 64) -> dict:
    """Per-tuple round trips vs pouch-batched framing: ``put_many`` +
    ``take_batch`` are one frame each, so a full pouch pair costs exactly
    2 round trips (the counter proves it) while the per-tuple loop pays
    2 per item."""
    from repro.core.space.remote import RemoteBackend
    rb = RemoteBackend(server_spec="sharded")
    try:
        t0 = time.perf_counter()
        for i in range(ops):
            rb.put(("one", i), i)
        for i in range(ops):
            rb.try_get(("one", i))
        per_tuple = 2 * ops / (time.perf_counter() - t0)
        rounds = max(ops // batch, 1)
        rt0 = rb.round_trips
        t0 = time.perf_counter()
        for r in range(rounds):
            rb.put_many([(("b", r, j), j) for j in range(batch)])
            rb.take_batch(("b", r, ANY), batch, timeout=5.0)
        batched = 2 * rounds * batch / (time.perf_counter() - t0)
        rt_per_pair = (rb.round_trips - rt0) / rounds
        return {"per_tuple": per_tuple, "batched": batched,
                "rt_per_pair": rt_per_pair}
    finally:
        rb.close()


def bench_remote_cached_read(ops: int) -> dict:
    """Hot reads of a version-keyed subject served from the
    invalidation-coherent client cache vs an uncached subject that
    round-trips every time."""
    from repro.core.space.remote import RemoteBackend
    rb = RemoteBackend(server_spec="sharded")    # caches "w"/"b"/"wver"
    try:
        rb.put(("w", 0), list(range(64)))
        rb.put(("q", 0), list(range(64)))
        rb.read(("w", 0))                        # prime the cache
        t0 = time.perf_counter()
        for _ in range(ops):
            rb.read(("w", 0))
        cached = ops / (time.perf_counter() - t0)
        t0 = time.perf_counter()
        for _ in range(ops):
            rb.read(("q", 0))
        uncached = ops / (time.perf_counter() - t0)
        return {"cached": cached, "uncached": uncached,
                "hits": rb.cache_hits}
    finally:
        rb.close()


def bench_rows(smoke: bool = True) -> list[tuple[str, float, str]]:
    """Remote tuple-space rows for the benchmarks/run.py harness (each
    spawns a private server; persisted to BENCH_<n>.json like every
    harness row)."""
    ops = 1_000 if smoke else 5_000
    n_threads = 4 if smoke else 8
    rows: list[tuple[str, float, str]] = []
    rate = bench_remote_contended(n_threads, ops // 2)
    rows.append((f"ts_remote_contended_putget_{n_threads}t", 1e6 / rate,
                 f"ops_per_s={rate:,.0f} (one pipelined connection)"))
    pb = bench_remote_pouch_batching(ops)
    rows.append(("ts_remote_pouch_batching", 1e6 / pb["batched"],
                 f"per_tuple={pb['per_tuple']:,.0f}/s "
                 f"batched={pb['batched']:,.0f}/s "
                 f"speedup={pb['batched'] / pb['per_tuple']:.1f}x "
                 f"rt_per_pouch_pair={pb['rt_per_pair']:.1f}"))
    cr = bench_remote_cached_read(ops)
    rows.append(("ts_remote_cached_read", 1e6 / cr["cached"],
                 f"cached={cr['cached']:,.0f}/s "
                 f"uncached={cr['uncached']:,.0f}/s "
                 f"speedup={cr['cached'] / max(cr['uncached'], 1e-9):.1f}x "
                 f"cache_hits={cr['hits']}"))
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--threads", type=int, default=8)
    ap.add_argument("--ops", type=int, default=20_000,
                    help="ops per thread in contended phases")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run (4 threads, 4k ops), same gate")
    ap.add_argument("--remote", action="store_true",
                    help="also run the PR 10 remote-backend rows "
                         "(private server per row)")
    args = ap.parse_args()
    if args.smoke:
        args.threads, args.ops = 4, 4_000

    results: dict[str, dict[str, float]] = {b: {} for b in BACKENDS}
    for spec in BACKENDS:
        put_rate, get_rate = bench_single_thread(spec, args.ops)
        results[spec]["1thread_put"] = put_rate
        results[spec]["1thread_get"] = get_rate
        results[spec][f"contended_putget_{args.threads}t"] = \
            bench_contended_putget(spec, args.threads, args.ops)
        results[spec][f"blocking_pipeline_{args.threads}t"] = \
            bench_blocking_pipeline(spec, args.threads, args.ops // 2)
        results[spec]["done_poll_5k_live"] = \
            bench_done_polling(spec, live=5_000, polls=20_000)
        results[spec]["take_batch_16"] = \
            bench_take_batch(spec, args.ops, batch=16)

    phases = list(results[BACKENDS[0]])
    width = max(len(p) for p in phases) + 2
    header = "phase".ljust(width) + "".join(b.rjust(16) for b in BACKENDS) \
        + "sharded/local".rjust(16)
    print(header)
    print("-" * len(header))
    for phase in phases:
        row = phase.ljust(width)
        for b in BACKENDS:
            row += f"{results[b][phase]:>14,.0f}/s"
        ratio = results["sharded"][phase] / results["local"][phase]
        row += f"{ratio:>15.2f}x"
        print(row)

    if args.remote:
        print("\nremote backend (PR 10):")
        for name, us, derived in bench_rows(smoke=args.smoke):
            print(f"  {name}: {derived} ({us:.1f} us/op)")

    key = f"contended_putget_{args.threads}t"
    speedup = results["sharded"][key] / results["local"][key]
    ok = speedup >= 2.0
    print(f"\nacceptance: sharded vs local contended put/get "
          f"({args.threads} threads): {speedup:.2f}x "
          f"({'PASS' if ok else 'FAIL'}, target >= 2.0x)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
